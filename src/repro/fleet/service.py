"""The global tuning service (docs/fleet.md).

ROADMAP item 2: because :meth:`TuningDB.merge` is a commutative,
associative, idempotent lattice join, the TuningDB is a state-based CRDT —
eventually-consistent *remote* replication is free by construction.  This
module is the small amount of plumbing that cashes that in:

* :class:`TuningService` — a long-lived process holding the fleet's merged
  DB.  Hosts **push** scratch entries (a join), **pull** device-matched
  finals (exact :class:`~repro.fleet.fingerprint.DeviceFingerprint` hit,
  falling back to the ``nearest_tuned`` nearest-device entry as a warm
  start), and **sync** (push + full pull back — one anti-entropy round).
  The service persists its DB to a path, so a restart resumes mid-fleet.
* :class:`ServiceClient` — the robustness layer every host talks through:
  per-request timeouts live in the transport, the client adds bounded
  exponential backoff with seeded jitter, idempotent retries (safe
  *because* push is a join), and graceful degradation — after retries are
  exhausted the client marks itself unavailable and ``try_*`` calls
  return ``None``/``False`` instead of raising, so tuning continues
  local-only; any later success flips it back to available.
* :class:`AntiEntropySync` — the host-side reconciliation loop: each round
  pushes the local DB, merges the service's state back, and applies the
  service's pending **re-tune requests** (fleet-wide drift propagation)
  by demoting locally and, when a :class:`~repro.fleet.drift.DriftMonitor`
  is attached, scheduling the demote → re-tune → canary lifecycle on the
  matching live op state.
* :func:`serve_http` — the service on a stdlib ``http.server`` endpoint
  (one POST /rpc route speaking ``{"op", "payload"}`` JSON); no new deps.

Demotion is the one operation that is *not* a plain join: ``merge`` must
stay commutative, so a final best always beats a demoted copy of itself —
which would let host A's stale final resurrect a winner host B just
demoted (the lost-demotion race ISSUE 7 names).  The service therefore
reconciles demotions causally, outside the join: a pushed ``demoted``
marker matching the service's live final (same point, same cost) demotes
the service copy and registers a **re-tune request**; after every
subsequent merge the service re-demotes any final that is byte-identical
to the demoted record (a stale re-promotion) and clears the request the
moment a *different* final lands (the re-tune's verdict — a new winner, or
the incumbent re-finalized at its freshly observed cost).  Both host's
markers survive: the demotion holds service-side until exactly one new
completed search supersedes it.
"""
from __future__ import annotations

import json
import random
import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.db import TuningDB
from repro.core.params import BasicParams
from repro.obs.trace import current_tracer

from .transport import Transport, TransportError

PROTOCOL_VERSION = 1


class ServiceUnavailable(TransportError):
    """Every retry failed; the caller should degrade to local-only tuning."""


# ---------------------------------------------------------------------------
# The service
# ---------------------------------------------------------------------------


class TuningService:
    """The fleet's merged TuningDB behind a tiny op-dispatch protocol.

    ``path`` (optional) binds the DB to disk: every mutating op flushes, so
    a restarted service (``TuningService(path=...)`` again) resumes with
    everything any host ever pushed — the ppOpen-AT "results survive the
    run" discipline at fleet scope.
    """

    def __init__(self, path: Optional[str] = None, db: Optional[TuningDB] = None) -> None:
        self.db = db if db is not None else TuningDB(path)
        self._lock = threading.Lock()
        # fp -> the exact best record that was demoted; pending until a
        # *different* final lands for that fingerprint (see module docs)
        self._retune: Dict[str, Dict[str, Any]] = {}
        # fp -> {"demoted": record, "final": winner}: a satisfied request
        # keeps guarding.  The join resolves finals by lower cost, so a
        # stale final (recorded at the pre-drift cost) would beat the
        # re-tune's verdict (recorded at the honest, higher observed cost)
        # in every later merge; the guard restores the verdict whenever a
        # byte-identical copy of the demoted record resurfaces as final.
        self._superseded: Dict[str, Dict[str, Any]] = {}
        self.stats: Dict[str, int] = {
            "push": 0, "pull": 0, "sync": 0, "demote": 0, "health": 0,
            "entries_received": 0, "demotions_reconciled": 0,
        }

    # -- transport entry point ------------------------------------------------

    def handle(self, op: str, payload: Dict[str, Any]) -> Dict[str, Any]:
        """One protocol operation — the single seam every transport calls."""
        tr = current_tracer()
        if tr is None:
            return self._handle(op, payload)
        with tr.span("service.handle", cat="fleet", op=op) as attrs:
            resp = self._handle(op, payload)
            attrs["ok"] = bool(resp.get("ok", True))
            return resp

    def _handle(self, op: str, payload: Dict[str, Any]) -> Dict[str, Any]:
        payload = payload or {}
        if op == "health":
            self.stats["health"] += 1
            return {"ok": True, "protocol": PROTOCOL_VERSION,
                    "entries": len(self.db.fingerprints()),
                    "retune_pending": len(self._retune)}
        if op == "push":
            return self.push(payload.get("entries") or {})
        if op == "pull":
            return self.pull(payload["bp"],
                             match=tuple(payload.get("match") or ("kernel",)))
        if op == "sync":
            return self.sync(payload.get("entries") or {})
        if op == "demote":
            return self.demote(payload["bp"])
        raise ValueError(f"unknown service op {op!r}")

    # -- operations -----------------------------------------------------------

    def push(self, entries: Dict[str, Dict[str, Any]]) -> Dict[str, Any]:
        """Join pushed entries into the service DB (idempotent, retry-safe).

        Demoted markers in the push are reconciled causally before and
        after the join — see the module docstring for why this cannot live
        inside ``merge`` itself.
        """
        with self._lock:
            self.stats["push"] += 1
            return self._join_locked(entries)

    def _join_locked(self, entries: Dict[str, Dict[str, Any]]) -> Dict[str, Any]:
        self.stats["entries_received"] += len(entries)
        self._register_demotions(entries)
        self.db.merge(entries)
        self._reapply_demotions()
        self._persist()
        return {"ok": True, "merged": len(entries),
                "entries": len(self.db.fingerprints())}

    def pull(self, bp_entries: Dict[str, Any],
             match: Tuple[str, ...] = ("kernel",)) -> Dict[str, Any]:
        """Device-matched final for ``bp``, else the nearest tuned entry.

        ``found`` is ``"final"`` (exact fingerprint, completed search — the
        caller may adopt it with zero evaluations), ``"nearest"`` (a
        different shape class / device — a warm-start seed, never adopted
        verbatim), or ``None``.  Either way the full DB entry rides along,
        so the caller just merges it and the existing warm-start machinery
        (``TuningDB.nearest_tuned`` + ``project_point``) does the rest.
        """
        bp = BasicParams.make(**bp_entries)
        with self._lock:
            self.stats["pull"] += 1
            fp = bp.fingerprint()
            if self.db.tuned_point(bp) is not None:
                return {"found": "final", "fingerprint": fp,
                        "entry": self.db.export_entries([fp])[fp]}
            near = self.db.nearest_tuned(bp, match=match)
            if near is not None:
                nfp = near["fingerprint"]
                return {"found": "nearest", "fingerprint": nfp,
                        "distance": near["distance"],
                        "entry": self.db.export_entries([nfp])[nfp]}
            return {"found": None}

    def sync(self, entries: Dict[str, Dict[str, Any]]) -> Dict[str, Any]:
        """One anti-entropy round: join theirs, return everything + retunes."""
        with self._lock:
            self.stats["sync"] += 1
            resp = self._join_locked(entries)
            return {"ok": True, "entries": self.db.export_entries(),
                    "retune": {fp: dict(rec) for fp, rec in self._retune.items()},
                    "total": resp["entries"]}

    def demote(self, bp_entries: Dict[str, Any]) -> Dict[str, Any]:
        """Explicit fleet-wide demotion (a host's DriftMonitor tripped)."""
        bp = BasicParams.make(**bp_entries)
        with self._lock:
            self.stats["demote"] += 1
            fp = bp.fingerprint()
            record = self._best_record(fp)
            demoted = self.db.demote_fingerprint(fp)
            if demoted and record is not None:
                self._retune[fp] = {"point": record["point"],
                                    "cost": record["cost"]}
            self._persist()
            return {"ok": True, "demoted": demoted,
                    "pending": fp in self._retune}

    def retune_pending(self) -> Dict[str, Dict[str, Any]]:
        with self._lock:
            return {fp: dict(rec) for fp, rec in self._retune.items()}

    # -- demotion reconciliation (the non-join part) --------------------------

    def _best_record(self, fp: str) -> Optional[Dict[str, Any]]:
        entry = self.db._data.get(fp)
        best = entry.get("best") if entry else None
        return dict(best) if best else None

    def _register_demotions(self, entries: Dict[str, Dict[str, Any]]) -> None:
        """A pushed ``demoted`` marker becomes a pending re-tune request.

        Arrival order must not matter (the lost-demotion race): whether the
        stale final is already here (demote it now), arrives in this very
        push (the join resolves final-over-demoted, then
        :meth:`_reapply_demotions` knocks it back down), or arrives in a
        *later* push (the pending request catches it), the demotion holds.
        The one case that does NOT register is a service-side final that
        already differs from the demoted record — a completed re-tune
        landed first, so the demotion is stale news.
        """
        for fp, theirs in entries.items():
            their_best = (theirs or {}).get("best") or {}
            if not their_best.get("demoted"):
                continue
            record = {"point": their_best.get("point"),
                      "cost": their_best.get("cost")}
            ours = self._best_record(fp)
            if ours is not None and ours.get("final"):
                if (ours.get("point") == record["point"]
                        and ours.get("cost") == record["cost"]):
                    self.db.demote_fingerprint(fp)
                    self._retune[fp] = record
                    self.stats["demotions_reconciled"] += 1
                # else: a different final already superseded the demotion
            else:
                self._retune[fp] = record
                self.stats["demotions_reconciled"] += 1

    def _reapply_demotions(self) -> None:
        """After a join: stale re-promotions fall, satisfied requests clear.

        A pending request holds the exact record that was demoted.  If the
        merge resurrected a final byte-identical to it (host A's stale copy
        of the very same claim), demote again; if a *different* final landed
        (a completed re-tune — new point, or the same point re-finalized at
        a freshly observed cost), the request is satisfied and becomes a
        *guard*: any later resurrection of the demoted record is overwritten
        with the re-tune's verdict (the join alone would pick the stale
        record — it carries the lower, pre-drift cost).
        """
        for fp, guard in self._superseded.items():
            best = self._best_record(fp)
            if (best is not None and best.get("final")
                    and any(best.get("point") == rec["point"]
                            and best.get("cost") == rec["cost"]
                            for rec in guard["demoted"])):
                entry = self.db._data.get(fp)
                if entry is not None:
                    entry["best"] = json.loads(
                        json.dumps(guard["final"], default=str)
                    )
                    self.stats["demotions_reconciled"] += 1
        for fp in list(self._retune):
            pending = self._retune[fp]
            best = self._best_record(fp)
            if best is None or not best.get("final"):
                continue  # still demoted; request stays pending
            if (best.get("point") == pending["point"]
                    and best.get("cost") == pending["cost"]):
                self.db.demote_fingerprint(fp)
                self.stats["demotions_reconciled"] += 1
            else:
                guard = self._superseded.setdefault(
                    fp, {"demoted": [], "final": None}
                )
                guard["demoted"].append(dict(pending))
                guard["final"] = dict(best)
                del self._retune[fp]

    def _persist(self) -> None:
        if self.db.path:
            self.db.save()


# ---------------------------------------------------------------------------
# The client (robustness layer)
# ---------------------------------------------------------------------------


@dataclass
class ClientStats:
    attempts: int = 0
    retries: int = 0
    failures: int = 0        # calls that exhausted every retry
    reconnects: int = 0      # degraded -> available transitions
    pushed_entries: int = 0
    pulled_finals: int = 0
    pulled_seeds: int = 0
    syncs: int = 0
    retunes_received: int = 0

    def as_metrics(self) -> Dict[str, int]:
        """Flat numeric snapshot for the metrics registry
        (:func:`repro.obs.metrics.snapshot_stats` protocol)."""
        return {
            "attempts": self.attempts,
            "retries": self.retries,
            "failures": self.failures,
            "reconnects": self.reconnects,
            "pushed_entries": self.pushed_entries,
            "pulled_finals": self.pulled_finals,
            "pulled_seeds": self.pulled_seeds,
            "syncs": self.syncs,
            "retunes_received": self.retunes_received,
        }


class ServiceClient:
    """A host's handle on the tuning service, with the failure policy built in.

    Retries are safe by construction — every mutating op is an idempotent
    join — so the client retries each call up to ``retries`` times with
    bounded exponential backoff (``backoff_base * 2**attempt``, capped at
    ``backoff_cap``) and seeded jitter (a uniform 0.5–1.5× factor, so a
    fleet of hosts losing the same service does not retry in lockstep).
    ``sleep``/``now`` are injectable — tests drive the whole schedule on a
    :class:`~repro.fleet.transport.VirtualClock` with zero real waiting.

    When a call exhausts its retries the client flips to unavailable
    (:attr:`available`) and raises :class:`ServiceUnavailable`; the
    ``try_*`` variants catch that and return ``None``/``False`` so callers
    degrade to local-only tuning without scattering try/except.  While
    unavailable, ``try_*`` calls short-circuit with a *single* probe
    attempt instead of a full retry ladder — the hot loop must not stall
    on a dead service — and any success reconnects.
    """

    def __init__(
        self,
        transport: Transport,
        retries: int = 4,
        backoff_base: float = 0.05,
        backoff_cap: float = 2.0,
        jitter_seed: int = 0,
        sleep: Callable[[float], None] = time.sleep,
        now: Callable[[], float] = time.monotonic,
    ) -> None:
        if retries < 0:
            raise ValueError(f"retries must be >= 0, got {retries}")
        self.transport = transport
        self.retries = int(retries)
        self.backoff_base = float(backoff_base)
        self.backoff_cap = float(backoff_cap)
        self._rng = random.Random(jitter_seed)
        self._sleep = sleep
        self._now = now
        self.available = True
        self.stats = ClientStats()

    # -- core call machinery ---------------------------------------------------

    def backoff_s(self, attempt: int) -> float:
        """The bounded, jittered delay before retry ``attempt`` (0-based)."""
        base = min(self.backoff_cap, self.backoff_base * (2 ** attempt))
        return base * (0.5 + self._rng.random())

    def _call(self, op: str, payload: Dict[str, Any],
              retries: Optional[int] = None) -> Dict[str, Any]:
        tr = current_tracer()
        if tr is None:
            return self._call_exec(op, payload, retries)
        attempts_before = self.stats.attempts
        with tr.span("service.call", cat="fleet", op=op) as attrs:
            try:
                resp = self._call_exec(op, payload, retries)
            except ServiceUnavailable:
                attrs["attempts"] = self.stats.attempts - attempts_before
                attrs["outcome"] = "unavailable"
                raise
            attrs["attempts"] = self.stats.attempts - attempts_before
            attrs["outcome"] = "ok"
            return resp

    def _call_exec(self, op: str, payload: Dict[str, Any],
                   retries: Optional[int] = None) -> Dict[str, Any]:
        retries = self.retries if retries is None else retries
        last: Optional[BaseException] = None
        for attempt in range(retries + 1):
            self.stats.attempts += 1
            try:
                resp = self.transport.request(op, payload)
            except TransportError as e:
                last = e
                if attempt < retries:
                    self.stats.retries += 1
                    self._sleep(self.backoff_s(attempt))
                continue
            if not self.available:
                self.available = True
                self.stats.reconnects += 1
            return resp
        self.available = False
        self.stats.failures += 1
        raise ServiceUnavailable(f"{op}: {last}") from last

    def _degraded_retries(self) -> Optional[int]:
        """Single-probe mode while unavailable (reconnects on success)."""
        return 0 if not self.available else None

    # -- protocol --------------------------------------------------------------

    def health(self) -> Dict[str, Any]:
        return self._call("health", {})

    def push(self, db: TuningDB, fingerprints: Optional[list] = None) -> Dict[str, Any]:
        entries = db.export_entries(fingerprints)
        resp = self._call("push", {"entries": entries},
                          retries=self._degraded_retries())
        self.stats.pushed_entries += len(entries)
        return resp

    def pull(self, bp: BasicParams,
             match: Tuple[str, ...] = ("kernel",)) -> Dict[str, Any]:
        resp = self._call("pull", {"bp": bp.asdict(), "match": list(match)},
                          retries=self._degraded_retries())
        if resp.get("found") == "final":
            self.stats.pulled_finals += 1
        elif resp.get("found") == "nearest":
            self.stats.pulled_seeds += 1
        return resp

    def sync(self, db: TuningDB) -> Dict[str, Any]:
        """One anti-entropy round: push ours, merge the service's back.

        Returns the service response; the service's pending re-tune
        requests are under ``"retune"`` for the caller (AntiEntropySync)
        to apply.
        """
        resp = self._call("sync", {"entries": db.export_entries()},
                          retries=self._degraded_retries())
        db.merge(resp.get("entries") or {})
        self.stats.syncs += 1
        self.stats.retunes_received += len(resp.get("retune") or {})
        return resp

    def demote(self, bp: BasicParams) -> Dict[str, Any]:
        return self._call("demote", {"bp": bp.asdict()},
                          retries=self._degraded_retries())

    # -- graceful-degradation variants ----------------------------------------

    def try_push(self, db: TuningDB, fingerprints: Optional[list] = None) -> bool:
        try:
            self.push(db, fingerprints)
            return True
        except ServiceUnavailable:
            return False

    def try_pull(self, bp: BasicParams,
                 match: Tuple[str, ...] = ("kernel",)) -> Optional[Dict[str, Any]]:
        try:
            return self.pull(bp, match)
        except ServiceUnavailable:
            return None

    def try_sync(self, db: TuningDB) -> Optional[Dict[str, Any]]:
        try:
            return self.sync(db)
        except ServiceUnavailable:
            return None

    def try_demote(self, bp: BasicParams) -> bool:
        try:
            self.demote(bp)
            return True
        except ServiceUnavailable:
            return False


# ---------------------------------------------------------------------------
# Host-side anti-entropy loop
# ---------------------------------------------------------------------------


class AntiEntropySync:
    """Periodic host <-> service reconciliation (docs/fleet.md).

    Each :meth:`sync_once`:

    1. pushes the host's DB and merges the service's state back (one
       lattice-join round trip — after it, host ⊇ service-at-send-time and
       service ⊇ host-at-send-time, which is all eventual consistency
       needs);
    2. applies the service's pending **re-tune requests**: demote the
       fingerprint locally (so this host's dispatch fast path stops
       trusting the stale final) and, when a DriftMonitor plus a matching
       live op state are attached via :meth:`watch`, drive the full
       demote → background re-tune → canary lifecycle on this host too —
       drift seen by *one* host re-tunes the *fleet*.

    A failed round leaves the host fully functional on its local DB
    (``try_sync`` degrades, never raises); the next round is the reconnect
    probe.  ``start(interval_s)`` runs rounds on a daemon thread for
    long-lived processes; tests and the CLI call :meth:`sync_once`
    directly for determinism.
    """

    def __init__(
        self,
        client: ServiceClient,
        db: TuningDB,
        monitor: Optional[Any] = None,   # DriftMonitor (duck-typed)
        on_retune: Optional[Callable[[str, Dict[str, Any]], None]] = None,
    ) -> None:
        self.client = client
        self.db = db
        self.monitor = monitor
        self.on_retune = on_retune
        self._ops: List[Any] = []  # AutotunedOps whose states we can re-tune
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self.rounds = 0
        self.failed_rounds = 0
        self.retunes_applied = 0

    def watch(self, op: Any) -> "AntiEntropySync":
        """Register an AutotunedOp whose live states re-tune on request."""
        self._ops.append(op)
        return self

    # -- one round -------------------------------------------------------------

    def sync_once(self) -> Dict[str, Any]:
        tr = current_tracer()
        if tr is None:
            return self._sync_once()
        with tr.span("fleet.sync", cat="fleet", round=self.rounds + 1) as attrs:
            res = self._sync_once()
            attrs["degraded"] = res["degraded"]
            attrs["retunes"] = res["retunes"]
            return res

    def _sync_once(self) -> Dict[str, Any]:
        self.rounds += 1
        resp = self.client.try_sync(self.db)
        if resp is None:
            self.failed_rounds += 1
            return {"ok": False, "degraded": True, "retunes": 0}
        applied = 0
        for fp, record in (resp.get("retune") or {}).items():
            if self._apply_retune(fp, record):
                applied += 1
        self.retunes_applied += applied
        return {"ok": True, "degraded": False, "retunes": applied,
                "entries": len(self.db.fingerprints())}

    def _apply_retune(self, fp: str, record: Dict[str, Any]) -> bool:
        """One service-side re-tune request landing on this host."""
        demoted = self.db.demote_fingerprint(fp)
        if self.on_retune is not None:
            try:
                self.on_retune(fp, record)
            except Exception:
                pass  # observer bugs must not break reconciliation
        if self.monitor is not None:
            for op, state in self._live_states(fp):
                if self.monitor.request_retune(op, state, reason="fleet"):
                    return True
        return demoted

    def _live_states(self, fp: str):
        for op in self._ops:
            for state in op.states().values():
                if state.bp.fingerprint() == fp:
                    yield op, state

    # -- background loop -------------------------------------------------------

    def start(self, interval_s: float = 30.0) -> "AntiEntropySync":
        if self._thread is None or not self._thread.is_alive():
            self._stop.clear()

            def loop() -> None:
                while not self._stop.wait(interval_s):
                    self.sync_once()

            self._thread = threading.Thread(
                target=loop, name="repro-anti-entropy", daemon=True
            )
            self._thread.start()
        return self

    def stop(self, timeout: float = 5.0) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout)
            self._thread = None


# ---------------------------------------------------------------------------
# HTTP face (stdlib only)
# ---------------------------------------------------------------------------


def service_registry(service: TuningService) -> "Any":
    """A MetricsRegistry pre-wired with the service's op counters and a
    DB-summary collector (entries, finals, quarantines, truncation)."""
    from repro.obs.metrics import MetricsRegistry

    registry = MetricsRegistry()
    registry.register_stats(
        "tuning_service", service.stats,
        help="tuning-service op counters",
    )

    def _collect(reg: Any) -> None:
        from repro.obs.explain import db_summary

        with service._lock:
            summary = db_summary(service.db)
        summary["retune_pending"] = len(service._retune)
        for k, v in summary.items():
            reg.gauge(f"tuning_db_{k}", help="tuning DB summary").set(v)

    registry.register_collector(_collect)
    return registry


def serve_http(service: TuningService, host: str = "127.0.0.1", port: int = 0,
               registry: Any = None):
    """Expose ``service`` on a ThreadingHTTPServer; returns the server.

    One route: ``POST /rpc`` with ``{"op": ..., "payload": ...}`` JSON,
    mirroring :meth:`TuningService.handle`; ``GET /health`` for probes and
    ``GET /metrics`` for a Prometheus text exposition of the service's op
    counters plus DB summary gauges (pass ``registry`` to expose a custom
    :class:`~repro.obs.metrics.MetricsRegistry` instead).
    The server runs on a daemon thread — call ``server.shutdown()`` to
    stop.  ``port=0`` binds an ephemeral port (``server.server_address``).
    """
    from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

    metrics = registry if registry is not None else service_registry(service)

    class Handler(BaseHTTPRequestHandler):
        def _reply(self, code: int, body: Dict[str, Any]) -> None:
            data = json.dumps(body, default=str).encode()
            self.send_response(code)
            self.send_header("Content-Type", "application/json")
            self.send_header("Content-Length", str(len(data)))
            self.end_headers()
            self.wfile.write(data)

        def _reply_text(self, code: int, text: str) -> None:
            data = text.encode()
            self.send_response(code)
            self.send_header("Content-Type", "text/plain; version=0.0.4")
            self.send_header("Content-Length", str(len(data)))
            self.end_headers()
            self.wfile.write(data)

        def do_GET(self) -> None:  # noqa: N802 (stdlib naming)
            if self.path == "/health":
                self._reply(200, service.handle("health", {}))
            elif self.path == "/metrics":
                try:
                    self._reply_text(200, metrics.prometheus_text())
                except Exception as e:  # exposition must not kill the service
                    self._reply(500, {"error": f"{type(e).__name__}: {e}"})
            else:
                self._reply(404, {"error": f"unknown path {self.path}"})

        def do_POST(self) -> None:  # noqa: N802
            if self.path != "/rpc":
                self._reply(404, {"error": f"unknown path {self.path}"})
                return
            try:
                length = int(self.headers.get("Content-Length", "0"))
                req = json.loads(self.rfile.read(length).decode())
                self._reply(200, service.handle(req.get("op", ""),
                                                req.get("payload") or {}))
            except Exception as e:  # a bad request must not kill the service
                self._reply(500, {"error": f"{type(e).__name__}: {e}"})

        def log_message(self, *args: Any) -> None:  # quiet CI logs
            pass

    server = ThreadingHTTPServer((host, port), Handler)
    thread = threading.Thread(
        target=server.serve_forever, name="repro-tuning-service", daemon=True
    )
    thread.start()
    return server
