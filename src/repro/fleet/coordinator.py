"""Sharded fleet search: N workers, one deterministic winner (docs/fleet.md).

ppOpen-AT's before-execution layer measures every generated candidate on one
machine, serially.  Nothing in that layer is sequential *in principle* —
candidates are independent — so the fleet coordinator partitions a
:class:`~repro.core.params.ParamSpace` across N workers and recovers the
single-process result by construction:

1. **shard** — ``space.shard(n, policy)`` deals every feasible point into
   exactly one shard (``stride`` round-robin or ``block`` contiguous);
2. **scatter** — each worker runs the *existing* search machinery
   (:class:`~repro.core.search.ExhaustiveSearch` by default, a
   :class:`~repro.core.search.StagedSearch` via ``search_factory``) over its
   shard, recording every trial into its own scratch
   :class:`~repro.core.db.TuningDB` — workers never contend on one entry;
3. **sync** — every ``sync_every`` trials a worker's scratch state is pushed
   out (thread backend: merged into the live target DB; spawn backend:
   flushed to the worker's scratch file), so a crashed fleet run resumes
   from whatever any worker had finished;
4. **merge barrier** — the coordinator unions all scratch DBs with
   :meth:`TuningDB.merge` (a deterministic lattice join: commutative,
   associative, idempotent), takes the argmin over the merged trials, and
   records it as the *final* best.  Because the shards partition the space
   and merge keeps the minimum cost per point, the fleet winner equals the
   single-process exhaustive winner for any worker count and shard policy.

Two worker backends: ``thread`` (in-process — XLA compilation releases the
GIL, so compile-dominated searches scale with cores, and closures work) and
``spawn`` (``multiprocessing`` — true parallelism for Python-bound costs;
the cost callable must be picklable, i.e. a module-level function or
instance).  Measured wall-clock finals on a *single* device should run with
``workers=1`` or a deterministic cost — concurrent timing on shared hardware
measures contention, not candidates.
"""
from __future__ import annotations

import json
import os
import time
from concurrent.futures import ProcessPoolExecutor, ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, Tuple

from repro.core.db import TuningDB
from repro.core.params import BasicParams, ParamSpace, PerfParam, pp_key
from repro.core.search import ExhaustiveSearch, Search, SearchResult, Trial

SHARD_POLICIES = ("stride", "block")
BACKENDS = ("thread", "spawn", "remote")


@dataclass
class WorkerReport:
    """What one fleet worker did — the operator/bench observability unit."""

    worker: int
    points: int                 # shard size (assigned candidates)
    evaluations: int            # cost evaluations the worker actually ran
    wall_s: float
    best_point: Dict[str, Any]
    best_cost: float
    scratch_path: Optional[str] = None
    resumed: int = 0            # trials recovered from a synced scratch DB
    crashed: bool = False       # the worker process died mid-shard

    def as_metrics(self) -> Dict[str, float]:
        """Flat numeric snapshot for the metrics registry
        (:func:`repro.obs.metrics.snapshot_stats` protocol)."""
        return {
            "points": self.points,
            "evaluations": self.evaluations,
            "wall_s": self.wall_s,
            "best_cost": self.best_cost,
            "resumed": self.resumed,
            "crashed": int(self.crashed),
        }


@dataclass
class FleetResult:
    """The merge barrier's output: the fleet winner plus per-worker stats."""

    result: SearchResult
    workers: List[WorkerReport] = field(default_factory=list)
    merged: Optional[TuningDB] = None
    shard_policy: str = "stride"
    backend: str = "thread"
    # remote backend only: did the barrier reconcile with the tuning
    # service (None = no service attached, False = degraded local-only)
    service_synced: Optional[bool] = None

    @property
    def best(self) -> Trial:
        return self.result.best

    @property
    def evaluations(self) -> int:
        return self.result.evaluations


def _shard_search(
    shard: ParamSpace,
    cost: Callable[[Mapping[str, Any]], float],
    bp: BasicParams,
    layer: str,
    scratch: TuningDB,
    sync_every: int,
    sync: Optional[Callable[[TuningDB], None]],
    search: Optional[Search],
) -> SearchResult:
    """Run one worker's shard with trial recording + periodic sync."""
    count = 0

    def recording_cost(point: Mapping[str, Any]) -> float:
        nonlocal count
        c = float(cost(point))
        scratch.record_trial(bp, point, c, layer)
        count += 1
        if sync is not None and sync_every > 0 and count % sync_every == 0:
            sync(scratch)
        return c

    return (search or ExhaustiveSearch()).run(shard, recording_cost)


def _space_from_points(points: Sequence[Mapping[str, Any]]) -> ParamSpace:
    """Rebuild an explicit-membership space from a pickled point list.

    A shard crosses the spawn boundary as plain dicts (constraints and
    parent spaces don't pickle); the worker re-wraps them so the existing
    Search strategies run unchanged.  Domains are the observed values —
    every listed point is feasible by construction (the parent filtered).
    """
    names = sorted(points[0])
    domains: Dict[str, List[Any]] = {n: [] for n in names}
    for p in points:
        for n in names:
            v = p[n]
            if all(repr(v) != repr(d) for d in domains[n]):
                domains[n].append(v)
    parent = ParamSpace([PerfParam(n, tuple(domains[n])) for n in names])
    return parent.subset(points)


def _spawn_worker(payload: Tuple) -> Tuple[int, List[Tuple[Dict, float]], float, int]:
    """Module-level spawn target (must be importable from the child).

    Crash-resume: when the worker's scratch file survives a previous run
    (the coordinator died, or this worker was killed and retried), its
    synced trials are recovered and only the *remaining* points are
    measured — an interrupted shard costs the unsynced tail, never the
    whole shard.
    """
    (idx, points, bp_entries, cost, layer, scratch_path, sync_every) = payload
    bp = BasicParams.make(**bp_entries)
    scratch = TuningDB()
    resumed = 0
    if scratch_path and os.path.exists(scratch_path):
        try:
            scratch.merge(TuningDB(scratch_path))
        except (ValueError, OSError):
            pass  # half-written scratch: re-measure the whole shard
        done = scratch.trials(bp)
        resumed = len(done)
        points = [p for p in points if pp_key(p) not in done]
    t0 = time.perf_counter()

    def sync(db: TuningDB) -> None:
        if scratch_path:
            db.save(scratch_path)

    if points:
        _shard_search(
            _space_from_points(points), cost, bp, layer, scratch,
            sync_every, sync, search=None,
        )
    sync(scratch)
    wall = time.perf_counter() - t0
    # all trials (resumed + new) so the parent's merge barrier sees the
    # recovered ones too; ``resumed`` lets it count real evaluations
    all_trials = [
        (json.loads(k), float(c))
        for k, c in sorted(scratch.trials(bp).items())
    ]
    return idx, all_trials, wall, resumed


class FleetCoordinator:
    """Deterministic scatter/merge orchestration of one PP search.

    Parameters mirror the ``launch/fleet.py`` CLI: ``workers`` (N),
    ``shard_policy`` (``stride``/``block``), ``backend``
    (``thread``/``spawn``/``remote``), ``sync_every`` (trials between
    scratch-DB syncs; 0 = barrier-only), ``scratch_dir`` (where
    per-worker scratch DBs persist; required for spawn crash-resume,
    optional for thread), and ``search_factory(worker_idx, shard) ->
    Search`` to run something other than exhaustive per shard (thread
    backend only — a staged search's prescreen closure doesn't pickle).

    The global-tuning-service extensions (docs/fleet.md):

    * ``service`` — a :class:`~repro.fleet.service.ServiceClient`.  Thread
      workers push scratch state on every periodic sync; every backend
      reconciles at the merge barrier (``sync`` = push + pull, so re-tune
      requests and other hosts' trials land here too) and pushes the
      final winner.  All service traffic is best-effort: a partitioned
      or dead service degrades the run to local-only, never fails it.
    * ``backend="remote"`` — thread workers plus a *mandatory* service:
      the topology for a multi-host fleet, where the service is the only
      shared state.
    * ``hosts``/``host_index`` — multi-host sharding: the space is first
      dealt across ``hosts`` (same shard policy), and this coordinator
      only measures host ``host_index``'s slice; the service's lattice
      join unions the host results, so the fleet winner still equals the
      single-process winner once every host has pushed.
    * ``keep_scratch`` — leave per-worker scratch files on disk after a
      successful barrier.  Default off: the barrier removes this run's
      scratch files *and* any orphaned ``fleet_worker_*.json`` left by a
      previous crashed run in the same ``scratch_dir`` (their synced
      trials have either been recovered by resume or superseded).
    """

    def __init__(
        self,
        workers: int = 2,
        shard_policy: str = "stride",
        backend: str = "thread",
        sync_every: int = 8,
        scratch_dir: Optional[str] = None,
        search_factory: Optional[Callable[[int, ParamSpace], Search]] = None,
        service: Optional[Any] = None,  # ServiceClient (duck-typed)
        hosts: int = 1,
        host_index: int = 0,
        keep_scratch: bool = False,
    ) -> None:
        if workers < 1:
            raise ValueError(f"workers must be >= 1, got {workers}")
        if shard_policy not in SHARD_POLICIES:
            raise ValueError(
                f"unknown shard policy {shard_policy!r}; expected {SHARD_POLICIES}"
            )
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; expected {BACKENDS}")
        if backend == "spawn" and search_factory is not None:
            raise ValueError("search_factory is thread-backend only "
                             "(search closures don't pickle)")
        if backend == "remote" and service is None:
            raise ValueError("backend 'remote' requires a service client "
                             "(the service is the only shared state)")
        if hosts < 1:
            raise ValueError(f"hosts must be >= 1, got {hosts}")
        if not 0 <= host_index < hosts:
            raise ValueError(
                f"host_index must be in [0, {hosts}), got {host_index}"
            )
        self.workers = workers
        self.shard_policy = shard_policy
        self.backend = backend
        self.sync_every = sync_every
        self.scratch_dir = scratch_dir
        self.search_factory = search_factory
        self.service = service
        self.hosts = hosts
        self.host_index = host_index
        self.keep_scratch = keep_scratch

    # -- public ----------------------------------------------------------------

    def search(
        self,
        space: ParamSpace,
        cost: Callable[[Mapping[str, Any]], float],
        bp: Optional[BasicParams] = None,
        db: Optional[TuningDB] = None,
        layer: str = "before_execution",
    ) -> FleetResult:
        """Scatter ``space`` across the fleet, merge, return the winner.

        ``db`` (optional) is the live target: thread workers sync their
        scratch results into it every ``sync_every`` trials, and the merge
        barrier lands the union plus the final best there.  Without it the
        merged view lives on :attr:`FleetResult.merged` only.

        With a ``service`` attached the barrier also reconciles globally:
        after the local scratch union it syncs with the service (pushing
        this host's trials, pulling every other host's), takes the argmin
        over the *union*, records that as final, and pushes the final
        entry back.  Trials partition across hosts and the join keeps the
        per-point minimum, so once the last host's barrier lands, the
        service-side final equals the single-process exhaustive winner.
        """
        bp = bp or BasicParams.make(kernel="fleet")
        if self.hosts > 1:
            host_shards = space.shard(self.hosts, self.shard_policy)
            if self.host_index >= len(host_shards):
                raise ValueError(
                    f"host {self.host_index} got an empty shard: the space "
                    f"has too few points for {self.hosts} hosts"
                )
            space = host_shards[self.host_index]
        shards = space.shard(self.workers, self.shard_policy)
        if self.backend == "spawn":
            reports, scratches = self._run_spawn(shards, cost, bp, layer)
        else:  # thread and remote both run in-process workers
            reports, scratches = self._run_threads(shards, cost, bp, layer, db)

        # The merge barrier.  TuningDB.merge is a deterministic lattice
        # join, so the landing order of scratch DBs cannot change the
        # merged state — the fleet-equivalence property the tests pin.
        merged = db if db is not None else TuningDB()
        for scratch in scratches:
            merged.merge(scratch)

        service_synced: Optional[bool] = None
        if self.service is not None:
            # push our trials / pull everyone else's, *then* take the
            # argmin — the recorded final reflects the global union, not
            # just this host's slice.  Best-effort: a dead service
            # degrades to local-only (service_synced=False).
            service_synced = self.service.try_sync(merged) is not None

        trials = merged.trials(bp)
        if not trials:
            raise ValueError("fleet search produced no trials")
        best_key = min(trials, key=lambda k: (trials[k], k))
        best = Trial(json.loads(best_key), float(trials[best_key]))
        merged.record_best(bp, best.point, best.cost, layer)

        if self.service is not None and service_synced:
            service_synced = self.service.try_push(
                merged, [bp.fingerprint()]
            )

        if not self.keep_scratch:
            self._cleanup_scratch(scratches)

        all_trials = [Trial(json.loads(k), float(c)) for k, c in sorted(trials.items())]
        result = SearchResult(
            best=best, trials=all_trials,
            evaluations=sum(r.evaluations for r in reports),
        )
        return FleetResult(
            result=result, workers=reports, merged=merged,
            shard_policy=self.shard_policy, backend=self.backend,
            service_synced=service_synced,
        )

    def as_search(
        self,
        bp: Optional[BasicParams] = None,
        db: Optional[TuningDB] = None,
        layer: str = "before_execution",
    ) -> "FleetSearch":
        """This coordinator as a plain Search — the Tuner/AutotunedOp hook."""
        return FleetSearch(self, bp=bp, db=db, layer=layer)

    # -- backends --------------------------------------------------------------

    def _scratch_path(self, idx: int) -> Optional[str]:
        if not self.scratch_dir:
            return None
        os.makedirs(self.scratch_dir, exist_ok=True)
        return os.path.join(self.scratch_dir, f"fleet_worker_{idx}.json")

    def _cleanup_scratch(self, scratches: List[TuningDB]) -> None:
        """Remove this run's scratch files + orphans after a clean barrier.

        Orphans are ``fleet_worker_*.json`` left behind by a previous run
        that crashed before *its* barrier (e.g. a larger worker count):
        their synced trials were either recovered by crash-resume or
        superseded by this run, so keeping them only risks a stale resume.
        """
        if not self.scratch_dir:
            return
        paths = {s.path for s in scratches if s.path}
        try:
            for name in os.listdir(self.scratch_dir):
                full = os.path.join(self.scratch_dir, name)
                if full in paths or (
                    name.startswith("fleet_worker_") and name.endswith(".json")
                ):
                    try:
                        os.remove(full)
                    except OSError:
                        pass  # already gone / permissions: never fail a run
        except OSError:
            pass

    def _run_threads(
        self, shards, cost, bp, layer, target: Optional[TuningDB]
    ) -> Tuple[List[WorkerReport], List[TuningDB]]:
        scratches = [TuningDB(self._scratch_path(i)) for i in range(len(shards))]
        service = self.service

        def sync(scratch: TuningDB) -> None:
            if target is not None:
                target.merge(scratch)
            if service is not None:
                # periodic push keeps the service warm mid-run, so other
                # hosts' pulls and crash-resume see partial progress.
                # Best-effort by construction: push is an idempotent join,
                # a drop just waits for the next sync or the barrier.
                service.try_push(scratch)

        has_sync = target is not None or service is not None
        sync_fn = sync if has_sync else None

        def run(idx: int) -> WorkerReport:
            shard = shards[idx]
            search = (
                self.search_factory(idx, shard) if self.search_factory else None
            )
            t0 = time.perf_counter()
            result = _shard_search(
                shard, cost, bp, layer, scratches[idx],
                self.sync_every, sync_fn, search,
            )
            return WorkerReport(
                worker=idx,
                points=sum(1 for _ in shard.points()),
                evaluations=result.evaluations,
                wall_s=time.perf_counter() - t0,
                best_point=dict(result.best.point),
                best_cost=float(result.best.cost),
                scratch_path=scratches[idx].path,
            )

        with ThreadPoolExecutor(max_workers=len(shards)) as pool:
            reports = list(pool.map(run, range(len(shards))))
        return reports, scratches

    def _run_spawn(
        self, shards, cost, bp, layer
    ) -> Tuple[List[WorkerReport], List[TuningDB]]:
        import multiprocessing as mp

        payloads = []
        shard_points = []
        for idx, shard in enumerate(shards):
            points = [dict(p) for p in shard.points()]
            shard_points.append(points)
            payloads.append((
                idx, points, bp.asdict(), cost, layer,
                self._scratch_path(idx), self.sync_every,
            ))
        ctx = mp.get_context("spawn")
        outcomes: Dict[int, Tuple[List[Tuple[Dict, float]], float, int]] = {}
        crashed: List[int] = []
        with ProcessPoolExecutor(
            max_workers=len(shards), mp_context=ctx
        ) as pool:
            futures = {
                idx: pool.submit(_spawn_worker, payloads[idx])
                for idx in range(len(shards))
            }
            for idx, fut in futures.items():
                try:
                    ridx, trials, wall, resumed = fut.result()
                    outcomes[ridx] = (trials, wall, resumed)
                except Exception:
                    # the worker process died mid-shard (os._exit, OOM
                    # kill, segfault) — a dying process also breaks the
                    # pool, so *sibling* futures can land here too.
                    # Either way the recovery below is the same.
                    crashed.append(idx)

        # Crash recovery: every trial the dead worker synced to its
        # scratch file survives; only the unsynced tail is re-measured —
        # in-parent, since the broken pool can't take new work.
        for idx in crashed:
            scratch = TuningDB()
            path = self._scratch_path(idx)
            if path and os.path.exists(path):
                try:
                    scratch.merge(TuningDB(path))
                except (ValueError, OSError):
                    pass  # half-written scratch: re-measure everything
            done = dict(scratch.trials(bp))
            remaining = [
                p for p in shard_points[idx] if pp_key(p) not in done
            ]
            t0 = time.perf_counter()
            if remaining:
                _shard_search(
                    _space_from_points(remaining), cost, bp, layer,
                    scratch, 0, None, search=None,
                )
            trials = [
                (json.loads(k), float(c))
                for k, c in sorted(scratch.trials(bp).items())
            ]
            outcomes[idx] = (trials, time.perf_counter() - t0, len(done))

        reports: List[WorkerReport] = []
        scratches: List[TuningDB] = []
        for idx in range(len(shards)):
            trials, wall, resumed = outcomes[idx]
            scratch = TuningDB()
            best_point, best_cost = None, float("inf")
            for point, c in trials:
                scratch.record_trial(bp, point, c, layer)
                if c < best_cost:
                    best_point, best_cost = dict(point), float(c)
            scratches.append(scratch)
            reports.append(WorkerReport(
                worker=idx, points=len(shard_points[idx]),
                evaluations=len(trials) - resumed, wall_s=wall,
                best_point=best_point or {}, best_cost=best_cost,
                scratch_path=self._scratch_path(idx),
                resumed=resumed, crashed=idx in crashed,
            ))
        return reports, scratches


class FleetSearch(Search):
    """Adapter making a :class:`FleetCoordinator` a drop-in Search strategy.

    ``Tuner(search=coordinator.as_search())`` (or
    ``AutotunedOp(search=...)``) routes the before-execution sweep through
    the fleet: the Tuner still owns trial caching and the final
    ``record_best`` against *its* DB; the coordinator's merge barrier runs
    against the adapter's scratch target.  Thread backend only in this
    position — the Tuner's caching cost is a closure.
    """

    def __init__(
        self,
        coordinator: FleetCoordinator,
        bp: Optional[BasicParams] = None,
        db: Optional[TuningDB] = None,
        layer: str = "before_execution",
    ) -> None:
        self.coordinator = coordinator
        self.bp = bp
        self.db = db
        self.layer = layer

    def run(self, space: ParamSpace, cost) -> SearchResult:
        fleet = self.coordinator.search(
            space, cost, bp=self.bp, db=self.db, layer=self.layer
        )
        return fleet.result
