"""Canned fleet workloads (docs/fleet.md).

Two kinds of search problem the fleet CLI / benchmarks / CI smoke drive:

* ``demo`` — a module-level *picklable* analytic cost over a small grid.
  This is what the ``multiprocessing`` spawn backend exercises in CI: no
  XLA, no example arrays, deterministic winner, byte-identical across
  worker counts and shard policies.
* the five registered Pallas kernels — real regions with small example
  inputs and a wall-clock cost closure (thread backend only: the closure
  holds live device arrays, which must not cross a spawn boundary).
"""
from __future__ import annotations

import json
import math
import os
import time
from typing import Any, Callable, Dict, Mapping, Tuple

from repro.core.params import ParamSpace, PerfParam

KERNELS = ("exb", "flash_attention", "rglru_scan", "ssm_scan", "stress")

# crashing_demo_cost control (set by crash-resume tests / the CI smoke):
# the JSON point whose first evaluation kills the worker process, and the
# marker file that makes the kill one-shot (so the resumed run completes).
CRASH_POINT_ENV = "REPRO_FLEET_CRASH_POINT"
CRASH_ONCE_ENV = "REPRO_FLEET_CRASH_ONCE"

DEMO_VARIANT_PENALTY = {"ij": 0.00, "ji": 0.07, "fused": 0.21}


def demo_space(blocks: int = 6) -> ParamSpace:
    """A small block × loop-variant grid (the paper's two PP axes)."""
    return ParamSpace([
        PerfParam("block", tuple(2 ** i for i in range(3, 3 + blocks))),
        PerfParam("variant", tuple(sorted(DEMO_VARIANT_PENALTY))),
    ])


def demo_cost(point: Mapping[str, Any]) -> float:
    """Deterministic analytic cost with a unique argmin (block=64, ij).

    Module-level on purpose: the spawn backend pickles this by reference.
    """
    return (
        abs(math.log2(int(point["block"]) / 64.0))
        + DEMO_VARIANT_PENALTY[str(point["variant"])]
    )


def crashing_demo_cost(point: Mapping[str, Any]) -> float:
    """``demo_cost`` with an env-driven one-shot worker kill (test seam).

    Module-level and picklable like :func:`demo_cost`, so it crosses the
    spawn boundary.  When ``REPRO_FLEET_CRASH_POINT`` holds a JSON point
    and ``REPRO_FLEET_CRASH_ONCE`` a marker-file path, the *first*
    evaluation of that point touches the marker and hard-kills the worker
    process (``os._exit`` — no cleanup, no excepthook, exactly what a
    SIGKILL/OOM looks like to the coordinator).  Later evaluations see the
    marker and behave normally, so crash-resume tests assert the second
    attempt completes from the synced scratch state.
    """
    poison = os.environ.get(CRASH_POINT_ENV)
    marker = os.environ.get(CRASH_ONCE_ENV)
    if poison and marker and not os.path.exists(marker):
        if json.loads(poison) == dict(point):
            with open(marker, "w") as f:
                f.write("crashed\n")
            os._exit(1)
    return demo_cost(point)


def example_args(name: str) -> Tuple[Any, ...]:
    """Small example inputs for one registered kernel (smoke-sized)."""
    import jax
    import jax.numpy as jnp

    key = jax.random.PRNGKey(0)
    if name == "flash_attention":
        q = jax.random.normal(key, (2, 256, 4, 64), jnp.float32)
        return (q, q, q)
    if name == "ssm_scan":
        seq, d = 256, 512
        ks = jax.random.split(key, 4)
        x = jax.random.normal(ks[0], (2, seq, d), jnp.float32)
        dt = jnp.full((2, seq, d), 0.01, jnp.float32)
        A = jax.random.normal(ks[1], (d, 16)) * 0.1
        Bc = jax.random.normal(ks[2], (2, seq, 16))
        Cc = jax.random.normal(ks[3], (2, seq, 16))
        D = jnp.ones((d,))
        return (x, dt, A, Bc, Cc, D)
    if name == "rglru_scan":
        seq, w = 256, 512
        ks = jax.random.split(key, 3)
        x = jax.random.normal(ks[0], (2, seq, w), jnp.float32)
        r = jax.nn.sigmoid(jax.random.normal(ks[1], (2, seq, w)))
        i = jax.nn.sigmoid(jax.random.normal(ks[2], (2, seq, w)))
        lam = jax.nn.sigmoid(jax.random.normal(key, (w,)))
        return (x, r, i, lam)
    if name == "exb":
        from repro.kernels.exb.ref import make_inputs

        return (make_inputs(key, dims=(16, 16, 128, 65)),)
    if name == "stress":
        from repro.kernels.stress.ref import make_inputs

        return (make_inputs(key, dims=(16, 16, 32)),)
    raise KeyError(f"unknown kernel {name!r}; known: {KERNELS} + ('demo',)")


def kernel_problem(name: str) -> Tuple[Any, ParamSpace, Callable[[Mapping[str, Any]], float]]:
    """(region, space, measured cost) for one registered kernel.

    The cost compiles (untimed) then takes a best-of-3 wall clock — the
    bench-grade measured cost, as a closure over the example args (thread
    backend only).
    """
    import jax

    from repro.core.registry import get_kernel

    spec = get_kernel(name)
    args = example_args(name)
    bp = spec.shape_class(*args)
    region = spec.make_region(bp)

    def cost(point: Mapping[str, Any]) -> float:
        fn = region.instantiate(point)
        jax.block_until_ready(fn(*args))  # compile, untimed
        best = math.inf
        for _ in range(3):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            best = min(best, time.perf_counter() - t0)
        return best

    return region, region.space, cost
