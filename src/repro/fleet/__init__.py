"""repro.fleet — the fleet tuning control plane (docs/fleet.md).

Three cooperating pieces, all installed on top of the unchanged core
search/DB machinery:

* :class:`~repro.fleet.fingerprint.DeviceFingerprint` — device identity as
  a composable BP dimension, so TuningDBs from heterogeneous hosts merge
  without clobbering and finals only transfer between matching targets;
* :class:`~repro.fleet.coordinator.FleetCoordinator` /
  :class:`~repro.fleet.coordinator.FleetSearch` — deterministic sharded
  search across N workers (threads or ``multiprocessing`` spawn) with a
  ``TuningDB.merge`` barrier that reproduces the single-process winner by
  construction;
* :class:`~repro.fleet.drift.DriftMonitor` — EWMA drift watch over the
  dispatch fast path's run-time trickle: demote a drifted final, re-tune
  off the hot path, canary the challenger, promote or roll back — every
  transition persisted in the DB's tuning-event log;
* :class:`~repro.fleet.service.TuningService` /
  :class:`~repro.fleet.service.ServiceClient` /
  :class:`~repro.fleet.service.AntiEntropySync` — the global tuning
  service: hosts push scratch DBs and pull device-matched finals over any
  :class:`~repro.fleet.transport.Transport` (in-process, stdlib HTTP, or
  the deterministic :class:`~repro.fleet.transport.FaultInjectionTransport`
  test seam), with bounded-backoff retries, local-only degradation under
  partition, and an anti-entropy loop that carries drift re-tune requests
  fleet-wide.
"""
from .coordinator import (
    BACKENDS,
    SHARD_POLICIES,
    FleetCoordinator,
    FleetResult,
    FleetSearch,
    WorkerReport,
)
from .drift import DriftMonitor
from .fingerprint import DeviceFingerprint, device_bp_entries, local_device
from .service import (
    AntiEntropySync,
    ClientStats,
    ServiceClient,
    ServiceUnavailable,
    TuningService,
    serve_http,
)
from .transport import (
    FaultInjectionTransport,
    FaultStats,
    HTTPTransport,
    InProcessTransport,
    Transport,
    TransportError,
    VirtualClock,
)

__all__ = [
    "BACKENDS",
    "SHARD_POLICIES",
    "AntiEntropySync",
    "ClientStats",
    "DeviceFingerprint",
    "DriftMonitor",
    "FaultInjectionTransport",
    "FaultStats",
    "FleetCoordinator",
    "FleetResult",
    "FleetSearch",
    "HTTPTransport",
    "InProcessTransport",
    "ServiceClient",
    "ServiceUnavailable",
    "Transport",
    "TransportError",
    "TuningService",
    "VirtualClock",
    "WorkerReport",
    "device_bp_entries",
    "local_device",
    "serve_http",
]
