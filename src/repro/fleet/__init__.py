"""repro.fleet — the fleet tuning control plane (docs/fleet.md).

Three cooperating pieces, all installed on top of the unchanged core
search/DB machinery:

* :class:`~repro.fleet.fingerprint.DeviceFingerprint` — device identity as
  a composable BP dimension, so TuningDBs from heterogeneous hosts merge
  without clobbering and finals only transfer between matching targets;
* :class:`~repro.fleet.coordinator.FleetCoordinator` /
  :class:`~repro.fleet.coordinator.FleetSearch` — deterministic sharded
  search across N workers (threads or ``multiprocessing`` spawn) with a
  ``TuningDB.merge`` barrier that reproduces the single-process winner by
  construction;
* :class:`~repro.fleet.drift.DriftMonitor` — EWMA drift watch over the
  dispatch fast path's run-time trickle: demote a drifted final, re-tune
  off the hot path, canary the challenger, promote or roll back — every
  transition persisted in the DB's tuning-event log.
"""
from .coordinator import (
    BACKENDS,
    SHARD_POLICIES,
    FleetCoordinator,
    FleetResult,
    FleetSearch,
    WorkerReport,
)
from .drift import DriftMonitor
from .fingerprint import DeviceFingerprint, device_bp_entries, local_device

__all__ = [
    "BACKENDS",
    "SHARD_POLICIES",
    "DeviceFingerprint",
    "DriftMonitor",
    "FleetCoordinator",
    "FleetResult",
    "FleetSearch",
    "WorkerReport",
    "device_bp_entries",
    "local_device",
]
