"""Drift detection + canary promotion (docs/fleet.md).

Mametjanov & Norris (arXiv:1309.1894) argue autotuning is a *sustained*
process: the environment drifts (thermal throttling, noisy neighbours,
driver updates), and a winner tuned yesterday can silently regress.  Our
dispatch fast path already feeds a trickle of measured call times to the
run-time layer (``monitor_every``); this module turns that trickle into a
supervised re-tuning lifecycle:

* **watch** — per shape class, an EWMA of observed cost.  While a *final*
  best is live and the EWMA exceeds its recorded cost by ``factor``, the
  class has drifted: the final is **demoted** in the DB
  (:meth:`~repro.core.db.TuningDB.demote_best`) so no fresh process freezes
  the stale winner, and a **re-tune is scheduled** — on the
  :class:`~repro.runtime.background_tuner.BackgroundTuner` worker when one
  is attached (the hot path never pays search cost), inline otherwise.

* **re-tune** — a *fresh* re-measure of the space
  (:meth:`AutotunedOp.retune_state`): recorded trial costs are exactly what
  reality drifted away from, so the cache must not short-circuit.

* **canary** — the challenger is selected *provisionally* (the region hot
  swaps, nothing is recorded final) for ``canary_window`` observations.  If
  its median observed cost beats what the incumbent was actually delivering
  (``incumbent_observed * canary_margin``) it is **promoted** — recorded as
  the new final best at its *observed* cost.  Otherwise it **rolls back**:
  the incumbent is re-selected and re-finalized at its observed cost, so
  the recorded expectation matches reality and the watch doesn't
  immediately re-trip.

Every transition lands in the DB's persisted tuning-event log
(``demoted`` → ``retune_scheduled`` → ``canary_start`` → ``promoted`` /
``rolled_back``, plus ``retune_failed``), the audit trail an operator —
or a test — replays to see why a host runs what it runs.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

from repro.core.autotuned import AutotunedOp, OpState


@dataclass
class _Watch:
    """Per-shape-class drift state machine."""

    phase: str = "healthy"  # healthy -> retuning -> canary -> healthy
    ewma: Optional[float] = None
    n: int = 0
    incumbent: Optional[Dict[str, Any]] = None
    incumbent_observed: float = 0.0
    challenger: Optional[Dict[str, Any]] = None
    canary_costs: List[float] = field(default_factory=list)

    def reset(self) -> None:
        self.phase = "healthy"
        self.ewma = None
        self.n = 0
        self.incumbent = None
        self.challenger = None
        self.canary_costs = []


class DriftMonitor:
    """Watches live costs, demotes drifted finals, canaries challengers.

    ``background`` (optional) runs re-tunes off the hot path; without it the
    re-tune runs synchronously inside :meth:`observe` (deterministic — the
    test/bench mode).  ``on_apply(state)`` fires after every selection the
    monitor makes (canary start and rollback) so callers mirroring
    selections elsewhere — the Server's DegreeController — stay in sync.
    """

    def __init__(
        self,
        background: Optional[Any] = None,  # BackgroundTuner (duck-typed)
        factor: float = 2.0,
        alpha: float = 0.25,
        min_observations: int = 4,
        canary_window: int = 4,
        canary_margin: float = 1.0,
        on_apply: Optional[Callable[[OpState], None]] = None,
    ) -> None:
        if factor <= 1.0:
            raise ValueError(f"drift factor must be > 1, got {factor}")
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"EWMA alpha must be in (0, 1], got {alpha}")
        self.background = background
        self.factor = factor
        self.alpha = alpha
        self.min_observations = max(1, min_observations)
        self.canary_window = max(1, canary_window)
        self.canary_margin = canary_margin
        self.on_apply = on_apply
        self.transitions: List[tuple] = []  # (fingerprint, kind) in order
        self._watches: Dict[str, _Watch] = {}
        self._lock = threading.Lock()

    # -- the run-time-layer feed ----------------------------------------------

    def observe(
        self,
        op: AutotunedOp,
        state: OpState,
        measured_cost: float,
        args: tuple = (),
        kwargs: Optional[dict] = None,
    ) -> Optional[str]:
        """Feed one measured cost for ``state``'s live selection.

        ``args``/``kwargs`` are the call's (example) arguments — captured at
        demotion time so the re-tune can measure candidates on real inputs.
        Returns the transition this observation triggered (``"demoted"``,
        ``"promoted"``, ``"rolled_back"``) or ``None``.
        """
        kwargs = kwargs or {}
        fp = state.bp.fingerprint()
        with self._lock:
            watch = self._watches.setdefault(fp, _Watch())
            if watch.phase == "canary":
                watch.canary_costs.append(float(measured_cost))
                if len(watch.canary_costs) < self.canary_window:
                    return None
                return self._verdict(op, state, watch)
            watch.ewma = (
                float(measured_cost) if watch.ewma is None
                else self.alpha * float(measured_cost)
                + (1.0 - self.alpha) * watch.ewma
            )
            watch.n += 1
            if watch.phase != "healthy":
                return None  # re-tune already in flight
            recorded = self._recorded_final_cost(op, state)
            if recorded is None or watch.n < self.min_observations:
                return None
            if watch.ewma <= self.factor * recorded:
                return None
            return self._demote(op, state, watch, recorded, args, kwargs)

    def watch_phase(self, state: OpState) -> str:
        with self._lock:
            watch = self._watches.get(state.bp.fingerprint())
            return watch.phase if watch else "healthy"

    def request_retune(
        self,
        op: AutotunedOp,
        state: OpState,
        args: tuple = (),
        kwargs: Optional[dict] = None,
        reason: str = "fleet",
    ) -> bool:
        """An externally requested demote → re-tune → canary (docs/fleet.md).

        The anti-entropy sync loop calls this when the global tuning
        service reports a fleet-wide re-tune request for a class this host
        is live-serving: drift observed by *one* host re-tunes every host.
        Unlike :meth:`observe` it does not wait for local evidence — the
        service's word is the trigger — but the challenger still earns its
        promotion through the normal canary window against this host's own
        observations.  Returns False when the class is already mid-
        lifecycle or has no recorded best to re-tune (the DB-side demotion
        has already landed via merge in that case).
        """
        kwargs = kwargs or {}
        with self._lock:
            watch = self._watches.setdefault(state.bp.fingerprint(), _Watch())
            if watch.phase != "healthy":
                return False
            recorded = op.db.best_cost(state.bp)
            if recorded is None:
                return False
            if watch.ewma is None:
                # no local observations yet: the recorded cost stands in as
                # what the incumbent was delivering, so the canary verdict
                # still has a bar to clear
                watch.ewma = float(recorded)
            self._demote(op, state, watch, float(recorded), args, kwargs,
                         reason=reason)
            return True

    # -- transitions -----------------------------------------------------------

    @staticmethod
    def _recorded_final_cost(op: AutotunedOp, state: OpState) -> Optional[float]:
        """The recorded cost of this class's *final* best, if one is live."""
        sig = getattr(state.region, "space_signature", None)
        if op.db.tuned_point(state.bp, space_signature=sig) is None:
            return None
        return op.db.best_cost(state.bp)

    def _demote(
        self,
        op: AutotunedOp,
        state: OpState,
        watch: _Watch,
        recorded: float,
        args: tuple,
        kwargs: dict,
        reason: str = "drift",
    ) -> str:
        """Caller holds the lock."""
        op.db.demote_best(state.bp)
        watch.incumbent = dict(state.region.selected)
        watch.incumbent_observed = float(watch.ewma)
        watch.phase = "retuning"
        self._log(op, state, "demoted",
                  observed=float(watch.ewma), recorded=float(recorded),
                  factor=self.factor, reason=reason,
                  point=dict(state.region.selected))
        mode = "background" if self.background is not None else "inline"
        self._log(op, state, "retune_scheduled", mode=mode)
        if self.background is not None:
            queued = self.background.submit_retune(
                op, state, args, kwargs,
                on_winner=lambda winner: self._on_challenger(op, state, winner),
            )
            if not queued:
                # the class is already queued/tuning on the worker (another
                # monitor or server racing on the same DB): no on_winner
                # will ever reach us, so re-arm instead of waiting forever —
                # the racer's verdict re-finalizes the entry and this watch
                # resumes supervising it
                self._log(op, state, "retune_failed", reason="already_inflight")
                watch.reset()
        else:
            # deterministic mode: re-tune right here (tests, benches).  The
            # lock is held — fine, the inline path is single-threaded.
            try:
                winner = op.retune_state(state, args, kwargs)
            except Exception:
                winner = None
            self._challenger_locked(op, state, winner)
        return "demoted"

    def _on_challenger(
        self, op: AutotunedOp, state: OpState, winner: Optional[Dict[str, Any]]
    ) -> None:
        """Background re-tune completion (worker thread)."""
        with self._lock:
            self._challenger_locked(op, state, winner)

    def _challenger_locked(
        self, op: AutotunedOp, state: OpState, winner: Optional[Dict[str, Any]]
    ) -> None:
        watch = self._watches.setdefault(state.bp.fingerprint(), _Watch())
        if winner is None:
            self._log(op, state, "retune_failed")
            watch.reset()
            return
        watch.challenger = dict(winner)
        watch.canary_costs = []
        watch.phase = "canary"
        # provisional hot apply: the canary window *runs* the challenger,
        # but nothing is recorded final until the verdict
        state.region.select(winner)
        self._log(op, state, "canary_start",
                  challenger=dict(winner), incumbent=watch.incumbent,
                  incumbent_observed=watch.incumbent_observed,
                  window=self.canary_window)
        self._apply(state)

    def _verdict(self, op: AutotunedOp, state: OpState, watch: _Watch) -> str:
        """Caller holds the lock; the canary window just filled."""
        costs = sorted(watch.canary_costs)
        challenger_observed = costs[len(costs) // 2]
        beats = challenger_observed < watch.incumbent_observed * self.canary_margin
        if beats:
            op.db.record_best(
                state.bp, watch.challenger, challenger_observed, "run_time"
            )
            self._log(op, state, "promoted",
                      challenger=dict(watch.challenger),
                      observed=float(challenger_observed),
                      incumbent_observed=float(watch.incumbent_observed))
            outcome = "promoted"
        else:
            state.region.select(watch.incumbent)
            # re-finalize the incumbent at what it actually delivers, so the
            # recorded expectation matches reality and the watch re-arms
            # instead of re-tripping on the very next observation
            op.db.record_best(
                state.bp, watch.incumbent, watch.incumbent_observed, "run_time"
            )
            self._log(op, state, "rolled_back",
                      challenger=dict(watch.challenger),
                      observed=float(challenger_observed),
                      incumbent=dict(watch.incumbent),
                      incumbent_observed=float(watch.incumbent_observed))
            self._apply(state)
            outcome = "rolled_back"
        watch.reset()
        return outcome

    # -- internals -------------------------------------------------------------

    def _log(self, op: AutotunedOp, state: OpState, kind: str, **payload) -> None:
        self.transitions.append((state.bp.fingerprint(), kind))
        op.db.record_event(state.bp, kind, **payload)

    def _apply(self, state: OpState) -> None:
        if self.on_apply is not None:
            try:
                self.on_apply(state)
            except Exception:
                pass  # a mirror-bookkeeping bug must not kill the watch
