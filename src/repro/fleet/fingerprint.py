"""Device fingerprinting — the fleet's BP dimension (docs/fleet.md).

The paper's premise is that "computers have diversified architectures":
ppOpen-AT re-runs its search per machine because a winner tuned on FX100
does not transfer to an Ivy Bridge Xeon.  Our TuningDB already keys entries
by shape class, traffic class, and mesh fingerprint — but not by *machine*,
so DBs from heterogeneous hosts would clobber each other's finals on merge.

:class:`DeviceFingerprint` closes that gap the same way
:class:`~repro.core.traffic.TrafficClass` did for serving traffic: it is a
small frozen record of the facts that decide whether a tuned winner
transfers — accelerator backend, platform/device kind, device count, host
core count, a power-of-two memory bucket, and the repro DB schema version —
that flattens into BP entries (:meth:`bp_entries`) and composes with any
shape class via ``BasicParams.with_entries``.

Recall semantics (wired in :class:`~repro.core.autotuned.AutotunedOp` via
``device_key=True``): a *final* best is recalled only for the exactly
matching device; any other device's final is still reachable as a
cross-device warm start through ``TuningDB.nearest_tuned`` — every
fingerprint field the devices disagree on adds distance, so the nearest
sibling *device* seeds the search when no same-device sibling class exists.

Memory is bucketed to a power of two GiB: two otherwise identical hosts
whose DIMMs differ by a few hundred MB must share tuning results, while a
64 GiB host must not adopt winners measured under 8 GiB pressure.
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Any, Dict, Mapping, Optional

_PREFIX = "device_"


@dataclass(frozen=True)
class DeviceFingerprint:
    """Identity of one tuning target, as a composable BP dimension."""

    backend: str       # jax.default_backend(): "cpu" / "gpu" / "tpu"
    platform: str      # device kind, e.g. "cpu", "TPU v5e", "NVIDIA H100"
    device_count: int  # visible accelerator devices
    host_cores: int    # os.cpu_count() — the paper's max-thread dimension
    memory_gib: int    # pow2 bucket of host memory
    schema: int        # repro TuningDB schema version

    BP_KEYS = (
        f"{_PREFIX}backend",
        f"{_PREFIX}platform",
        f"{_PREFIX}count",
        f"{_PREFIX}cores",
        f"{_PREFIX}mem_gib",
        f"{_PREFIX}schema",
    )

    @classmethod
    def detect(cls) -> "DeviceFingerprint":
        """Fingerprint the running host (cached — see :func:`local_device`)."""
        import jax

        from repro.core.db import SCHEMA_VERSION

        devices = jax.devices()
        return cls(
            backend=str(jax.default_backend()),
            platform=str(getattr(devices[0], "device_kind", devices[0].platform)),
            device_count=len(devices),
            host_cores=os.cpu_count() or 1,
            memory_gib=_pow2_bucket(_host_memory_gib()),
            schema=SCHEMA_VERSION,
        )

    def bp_entries(self) -> Dict[str, Any]:
        """Flat BP entries, mirroring ``TrafficClass.bp_entries`` /
        ``mesh_bp_entries`` so device identity composes orthogonally."""
        return {
            f"{_PREFIX}backend": self.backend,
            f"{_PREFIX}platform": self.platform,
            f"{_PREFIX}count": int(self.device_count),
            f"{_PREFIX}cores": int(self.host_cores),
            f"{_PREFIX}mem_gib": int(self.memory_gib),
            f"{_PREFIX}schema": int(self.schema),
        }

    @classmethod
    def from_bp_entries(cls, bp: Mapping[str, Any]) -> "DeviceFingerprint":
        return cls(
            backend=str(bp[f"{_PREFIX}backend"]),
            platform=str(bp[f"{_PREFIX}platform"]),
            device_count=int(bp[f"{_PREFIX}count"]),
            host_cores=int(bp[f"{_PREFIX}cores"]),
            memory_gib=int(bp[f"{_PREFIX}mem_gib"]),
            schema=int(bp[f"{_PREFIX}schema"]),
        )

    @property
    def label(self) -> str:
        return (
            f"{self.backend}/{self.platform.replace(' ', '_')}"
            f"x{self.device_count}/c{self.host_cores}/m{self.memory_gib}g"
            f"/v{self.schema}"
        )

    def arch_spec(self):
        """The architecture model for this device's backend.

        The :class:`~repro.core.arch.ArchSpec` is the *emit-layer* view of
        the same machine this fingerprint identifies: the fingerprint keys
        DB entries, the arch spec generates the candidate spaces searched
        under those keys (docs/arch.md).  Its ``arch_``-prefixed
        ``bp_entries()`` compose with these ``device_`` entries, so emitted
        spaces are namespaced per architecture fleet-wide.
        """
        from repro.core.arch import detect

        return detect(self.backend)


def _host_memory_gib() -> float:
    """Total host memory in GiB; 1.0 when undetectable (still deterministic)."""
    try:
        page = os.sysconf("SC_PAGE_SIZE")
        pages = os.sysconf("SC_PHYS_PAGES")
        if page > 0 and pages > 0:
            return (page * pages) / 2**30
    except (ValueError, OSError, AttributeError):
        pass
    return 1.0


def _pow2_bucket(gib: float) -> int:
    """Round up to the next power-of-two GiB (minimum 1)."""
    n = 1
    while n < gib:
        n *= 2
    return n


_LOCAL: Optional[DeviceFingerprint] = None


def local_device() -> DeviceFingerprint:
    """The running host's fingerprint, detected once per process.

    Detection touches ``jax.devices()`` (which initializes the backend), so
    it is deliberately lazy — importing :mod:`repro.fleet` must stay free.
    """
    global _LOCAL
    if _LOCAL is None:
        _LOCAL = DeviceFingerprint.detect()
    return _LOCAL


def device_bp_entries(device: Optional[DeviceFingerprint] = None) -> Dict[str, Any]:
    """BP entries for ``device`` (default: the running host).

    The one-liner shape-class extension: ``bp.with_entries(**device_bp_entries())``.
    """
    return (device or local_device()).bp_entries()
