"""End-to-end train-step benchmark: untuned vs per-kernel-greedy vs joint.

The paper's headline 1.801x is a whole-application number; this bench
measures the analogous property of our joint tuner (docs/program.md): for
each shape config it times one *full* train step under

* ``untuned``  — the config defaults (microbatch degree 1, configured remat);
* ``greedy``   — each program member tuned in isolation against the measured
  step (the per-kernel-greedy composition, PRs 1–3's strategy);
* ``joint``    — the :class:`~repro.core.program.JointSearch` winner over
  the member product, measured end to end.

Every composition's cost comes from the *same* joint-search trial table
(the search always evaluates the greedy and untuned compositions), so the
``joint <= greedy`` gate is a construction property of argmin-over-superset
— it can never flake on machine noise.

A deterministic ``interference`` config (an analytic cost with a
cross-member interaction: each knob alone prefers its default, the
composition prefers both flipped) proves the *strict* improvement case —
per-member greedy provably cannot find the joint optimum there.

Gates (raise, failing the run, when missed; CI re-checks them against
``benchmarks/baselines/train_step.json`` via
``scripts/check_bench_regression.py``):

* joint cost <= greedy cost on every config;
* joint cost < greedy cost on the interference config (strict).
"""
from __future__ import annotations

from typing import Dict

from .common import emit

# real-step shape configs: (label, global_batch, seq_len)
SHAPE_CONFIGS = (
    ("b4s32", 4, 32),
    ("b8s16", 8, 16),
)

ARCH = "tinyllama-1.1b"


def _member_greedy_tune(program, db, counter: Dict[str, int]) -> None:
    """Tune each member in isolation (others at defaults): the greedy stage."""
    from repro.core import AdaptiveWallClockCost, Tuner

    defaults = {m.name: dict(m.region.selected) for m in program.members}
    for member in program.members:
        def build(point, _member=member):
            assignment = {name: dict(sub) for name, sub in defaults.items()}
            assignment[_member.name] = dict(point)
            return program.build_executable(assignment)

        inner = AdaptiveWallClockCost(build, warmup=1, min_repeats=1, max_repeats=3)

        def cost(point, _inner=inner):
            counter["evals"] += 1
            return _inner(point)

        Tuner(db).tune(member.region, member.bp, cost, select=False)


def _flat(assignment) -> Dict:
    from repro.core import flatten_assignment

    return flatten_assignment(assignment)


def _run_real_config(label: str, batch: int, seq: int):
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.core import TuningDB
    from repro.data import SyntheticLMDataset
    from repro.optim import AdamWConfig
    from repro.runtime import Trainer, TrainLoopConfig

    cfg = get_config(ARCH, smoke=True)
    db = TuningDB()
    trainer = Trainer(
        cfg,
        AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=10),
        TrainLoopConfig(
            total_steps=1, n_microbatches=1, microbatch_candidates=(1, 2),
        ),
        tuning_db=db,
    )
    ds = SyntheticLMDataset(cfg, global_batch=batch, seq_len=seq, seed=7)
    example = {k: jnp.asarray(v) for k, v in ds.batch(0).items()}
    params, opt_state = trainer.init_state(jax.random.PRNGKey(0))
    program = trainer.train_program(params, opt_state, example)

    untuned = {m.name: dict(m.region.selected) for m in program.members}
    greedy_counter = {"evals": 0}
    _member_greedy_tune(program, db, greedy_counter)
    greedy = program.greedy_composition()

    result = program.tune(cap=None)  # exhaustive over the (tiny) product
    bp = program.fingerprint()
    joint_cost = float(result.cost)
    greedy_cost = db.trial_cost(bp, _flat(greedy))
    untuned_cost = db.trial_cost(bp, _flat(untuned))
    assert greedy_cost is not None and untuned_cost is not None, (
        "joint search must evaluate the greedy and untuned compositions"
    )

    emit(f"train_step/{label}/untuned", untuned_cost,
         f"point={_flat(untuned)}")
    emit(f"train_step/{label}/greedy", greedy_cost,
         f"point={_flat(greedy)};evals={greedy_counter['evals']}")
    emit(
        f"train_step/{label}/joint", joint_cost,
        f"point={result.point};evals={result.evaluations}"
        f";vs_greedy={joint_cost / greedy_cost:.3f}"
        f";vs_untuned={joint_cost / untuned_cost:.3f}",
    )
    return joint_cost, greedy_cost


def _run_interference_config():
    """Deterministic analytic program where greedy provably loses.

    Two members, each domain {1, 2}; the cost has an interaction term:
    flipping either knob alone regresses, flipping both wins — the shape of
    shared-resource coupling (two kernels that individually prefer large
    blocks but together thrash the same cache).  Coordinate-greedy from the
    default composition stays at (1, 1); only the joint search reaches
    (2, 2).
    """
    from repro.core import (
        ATRegion, BasicParams, ParamSpace, PerfParam, ProgramMember,
        ProgramSpec, Tuner, TuningDB,
    )

    table = {(1, 1): 1.0, (1, 2): 1.2, (2, 1): 1.2, (2, 2): 0.7}
    ra = ATRegion("a", ParamSpace([PerfParam("x", (1, 2))]), lambda p: (lambda: p))
    rb = ATRegion("b", ParamSpace([PerfParam("y", (1, 2))]), lambda p: (lambda: p))
    db = TuningDB()
    program = ProgramSpec(
        "interference",
        [
            ProgramMember("a", ra, bp=BasicParams.make(kernel="ia")),
            ProgramMember("b", rb, bp=BasicParams.make(kernel="ib")),
        ],
        db=db,
    )
    # greedy: each member tuned alone, the other at its default
    Tuner(db).tune(ra, program.members[0].bp,
                   lambda p: table[(p["x"], 1)], select=False)
    Tuner(db).tune(rb, program.members[1].bp,
                   lambda p: table[(1, p["y"])], select=False)
    greedy = program.greedy_composition()
    greedy_cost = table[(greedy["a"]["x"], greedy["b"]["y"])]

    result = program.tune(
        cost=lambda pt, budget=None: table[(pt["a.x"], pt["b.y"])], cap=None,
    )
    joint_cost = float(result.cost)
    emit("train_step/interference/greedy", greedy_cost, f"point={_flat(greedy)}")
    emit(
        "train_step/interference/joint", joint_cost,
        f"point={result.point};evals={result.evaluations}"
        f";vs_greedy={joint_cost / greedy_cost:.3f}",
    )
    return joint_cost, greedy_cost


def run() -> None:
    results = {}
    for label, batch, seq in SHAPE_CONFIGS:
        results[label] = _run_real_config(label, batch, seq)
    results["interference"] = _run_interference_config()

    violations = {
        label: (j, g) for label, (j, g) in results.items() if j > g
    }
    strict = sum(1 for j, g in results.values() if j < g)
    joint_le_greedy = int(not violations)
    emit(
        "train_step/summary",
        sum(j for j, _ in results.values()),
        f"joint_le_greedy={joint_le_greedy};strict={strict}"
        f";configs={len(results)}",
    )
    if violations or results["interference"][0] >= results["interference"][1]:
        raise RuntimeError(
            "joint tuning missed its acceptance gate: "
            f"joint>greedy on {sorted(violations)}; interference strict "
            f"improvement={results['interference']}"
        )


if __name__ == "__main__":
    run()
