"""Fleet tuning benchmark: 1 vs N workers on the five kernels (docs/fleet.md).

For each registered Pallas kernel this runs the same before-execution sweep
twice — single worker and ``WORKERS``-worker sharded
(:class:`~repro.fleet.FleetCoordinator`, thread backend) — using the
kernel's *deterministic* prescreen cost (the compile-only roofline /
analytic model of docs/tuning.md), so the two runs score identical numbers
and the gates cannot flake on machine noise:

* **identical winners** — the sharded fleet must return the single-process
  argmin for every kernel (the merge-barrier equivalence, gated);
* **full coverage** — fleet evaluations == |space| in both runs (gated);
* **balance** — shard sizes differ by at most one point (gated; per-worker
  work is 1/N of the space, which is what makes throughput scale);
* **throughput scaling** — back-to-back wall-time ratio of the two runs,
  emitted per kernel and in aggregate.  XLA lowering/compilation releases
  the GIL, so the thread fleet overlaps candidate compilation.  The ratio
  is gated (``min_speedup_full``) only in full mode — CI smoke runs under
  ``BENCH_FAST=1`` where the checker skips the timing gate (2-core runners
  make wall-clock ratios a coin toss; the deterministic gates still hold).

Rows: ``fleet_tune/<kernel>/single`` and ``.../fleet`` (wall seconds, with
``evals=``/``winner=`` derived), plus a ``fleet_tune/summary`` row carrying
the gate fields ``scripts/check_bench_regression.py`` reads against
``benchmarks/baselines/fleet_tune.json``.
"""
from __future__ import annotations

import json
import os
import time

from .common import emit

WORKERS = 2
KERNELS = ("exb", "flash_attention", "rglru_scan", "ssm_scan", "stress")


def _prescreen_cost(name):
    """The kernel's deterministic stage-1 cost over its example args."""
    from repro.core.cost import roofline_prescreen
    from repro.core.registry import get_kernel
    from repro.fleet.workloads import example_args

    spec = get_kernel(name)
    args = example_args(name)
    bp = spec.shape_class(*args)
    region = spec.make_region(bp)
    factory = spec.prescreen_factory or roofline_prescreen
    cost = factory(region, bp, args, {})
    if cost is None:  # no example args — cannot happen for these kernels
        raise RuntimeError(f"{name}: no prescreen cost available")
    return region, bp, cost


def run() -> None:
    from repro.core import BasicParams
    from repro.fleet import FleetCoordinator

    winners_match = 0
    balanced = True
    covered = True
    speedups = []

    for name in KERNELS:
        region, bp, single_cost = _prescreen_cost(name)
        space = region.space
        n_points = sum(1 for _ in space.points())  # feasible, not raw grid

        t0 = time.perf_counter()
        single = FleetCoordinator(workers=1).search(
            space, single_cost, bp=BasicParams.make(kernel=f"bench_single/{name}")
        )
        t_single = time.perf_counter() - t0
        emit(
            f"fleet_tune/{name}/single", t_single,
            f"evals={single.evaluations};"
            f"winner={json.dumps(single.best.point, sort_keys=True)}",
        )

        # fresh cost: the fleet run must pay its own compilations, not
        # replay the single run's cache (the timing comparison is honest)
        _, _, fleet_cost = _prescreen_cost(name)
        t0 = time.perf_counter()
        fleet = FleetCoordinator(workers=WORKERS).search(
            space, fleet_cost, bp=BasicParams.make(kernel=f"bench_fleet/{name}")
        )
        t_fleet = time.perf_counter() - t0
        sizes = [w.points for w in fleet.workers]
        emit(
            f"fleet_tune/{name}/fleet", t_fleet,
            f"evals={fleet.evaluations};workers={WORKERS};"
            f"shards={'/'.join(map(str, sizes))};"
            f"winner={json.dumps(fleet.best.point, sort_keys=True)}",
        )

        if fleet.best.point == single.best.point:
            winners_match += 1
        else:
            print(f"fleet_tune/{name}: WINNER MISMATCH "
                  f"single={single.best.point} fleet={fleet.best.point}")
        if not (single.evaluations == fleet.evaluations == n_points):
            covered = False
        if max(sizes) - min(sizes) > 1:
            balanced = False
        speedups.append(t_single / t_fleet if t_fleet > 0 else 1.0)

    agg_speedup = sum(speedups) / len(speedups)
    emit(
        "fleet_tune/summary", 0.0,
        f"winners_match={winners_match};kernels={len(KERNELS)};"
        f"covered={int(covered)};balanced={int(balanced)};"
        f"workers={WORKERS};speedup={agg_speedup:.2f};"
        # the speedup gate needs real parallel headroom: record the host's
        # core count so the checker can skip it on single-core runners
        f"cores={os.cpu_count() or 1}",
    )
    if winners_match != len(KERNELS):
        raise AssertionError(
            f"fleet equivalence violated on {len(KERNELS) - winners_match} "
            "kernel(s): sharded winner != single-process winner"
        )


if __name__ == "__main__":
    run()
