"""Roofline table (§Roofline deliverable) — reads the dry-run sweep results
(results/dryrun_baseline.jsonl, produced by ``python -m repro.launch.dryrun
--all --both-meshes``) and prints the per-cell three-term table as CSV.

Not a wall-clock benchmark: the three terms are compiled-artifact analysis
for the TPU v5e target (197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s ICI).
"""
from __future__ import annotations

import json
import os

from .common import emit

BASELINE = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun_baseline.jsonl")


def run(path: str = BASELINE) -> list:
    if not os.path.exists(path):
        emit("roofline/missing", 0.0, f"run dryrun --all first ({path})")
        return []
    rows = [json.loads(l) for l in open(path)]
    ok = [r for r in rows if r.get("status") == "ok"]
    from repro.configs import SHAPES, get_config
    from repro.models import analytic_step_flops

    for r in ok:
        t = r["roofline"]
        name = f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
        # recompute MODEL_FLOPS with the attention/scan-aware formula (rows
        # may predate it)
        cell = SHAPES[r["shape"]]
        r["model_flops"] = analytic_step_flops(
            get_config(r["arch"]), cell.kind, cell.global_batch, cell.seq_len
        )
        r["useful_flops_ratio"] = (
            r["model_flops"] / t["hlo_flops"] if t["hlo_flops"] else 0.0
        )
        frac = r.get("useful_flops_ratio") or 0.0
        # roofline fraction: ideal model-FLOPs time / achieved bound
        ideal = r["model_flops"] / (r["chips"] * 197e12)
        achieved = t["total_s"]
        emit(
            name,
            achieved,
            f"bottleneck={t['bottleneck']};C={t['compute_s']:.3e};"
            f"M={t['memory_s']:.3e};X={t['collective_s']:.3e};"
            f"useful_ratio={frac:.3f};roofline_frac={ideal / achieved:.4f};"
            f"mem_per_dev_GiB={r['memory']['per_device_total'] / 2**30:.2f}",
        )
    emit("roofline/cells_ok", 0.0, f"count={len(ok)}/{len(rows)}")
    return ok


if __name__ == "__main__":
    run()
