"""Shared benchmark utilities."""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

FAST = bool(int(os.environ.get("BENCH_FAST", "0")))

# Every emit() lands here too, so run.py can write the machine-readable
# BENCH_*.json perf record next to the human CSV on stdout.
RESULTS = []


def time_call(fn, *args, warmup=1, repeats=3):
    """Best-of wall time in seconds (paper methodology: many iterations,
    report the stable time; min suppresses scheduler noise)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


def emit(name: str, seconds: float, derived: str = "") -> None:
    RESULTS.append({"name": name, "us_per_call": seconds * 1e6, "derived": derived})
    print(f"{name},{seconds * 1e6:.1f},{derived}")
