"""Paper Fig. 11 — speedup of each Exchange/LoopFusion variant vs the
original GKV loop (directive on iz), all at the paper's 32-thread degree.

Paper result (FX100): directive-on-outermost is fastest at 1.791×.
This host is a 1-core CPU, so the *structure* effects (grain count, vector
shapes) are measured, not 32-way parallel speedup — relative ordering is the
reproduction target, absolute ratios are machine-specific.
"""
from __future__ import annotations

from .common import FAST, emit, time_call

import jax

from repro.apps import gkv
from repro.core import ExchangeVariant, GKV_FIGURE_OF_VARIANT, enumerate_exchange_variants

DEGREE = 32


def run() -> dict:
    key = jax.random.PRNGKey(0)
    dims = gkv.GKV_DIMS if not FAST else (("iv", 8), ("iz", 8), ("mx", 32), ("my", 17))
    inp = gkv.make_inputs(key, dims)
    nest = gkv.exb_nest(dims)

    results = {}
    original_time = None
    for v in enumerate_exchange_variants(4):
        fig = GKV_FIGURE_OF_VARIANT[(v.m, v.j)]
        fn = jax.jit(nest.variant_fn(v, DEGREE))
        t = time_call(fn, inp, warmup=1, repeats=2 if FAST else 3)
        results[fig] = t
        if (v.m, v.j) == (4, 2):
            original_time = t
    for fig, t in results.items():
        emit(f"fig11/{fig}", t, f"speedup_vs_original={original_time / t:.3f}")
    best = min(results, key=results.get)
    emit(
        "fig11/best", results[best],
        f"variant={best};speedup={original_time / results[best]:.3f}",
    )
    return results


if __name__ == "__main__":
    run()
