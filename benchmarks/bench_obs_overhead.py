"""Observability overhead benchmark: the disabled-tracing tax.

The tracing layer (docs/observability.md) promises to be zero-cost when
disabled: every instrumented seam guards with ``current_tracer() is
None`` and the dispatch fast path carries no tracer code at all.  This
bench measures that promise on the most instrumentation-dense workload —
a full tune, whose loop hits the ``tuner.tune`` + per-trial
``tuner.trial`` seams:

* ``off`` — wall time of a toy tune with no tracer installed (what every
  production run that doesn't pass ``--trace-out`` pays);
* ``on``  — the same tune with a live ring-buffer tracer (what a traced
  run pays; bounded, but allowed to cost more);
* ``guard`` — the per-call cost of the ``current_tracer()`` guard itself,
  measured directly.

The **off** gate is the contract: the disabled-path overhead — the guard
cost times the number of guard sites the workload actually crossed
(bounded above by the events the enabled run emitted) — must stay under
``MAX_OFF_PCT`` percent of the untraced wall time.  The enabled-path
ratio is gated loosely (``benchmarks/baselines/obs_overhead.json``) as a
canary against the tracer itself getting expensive.

Two more rows complete the picture: finalized *dispatch* latency with
tracing off vs. on (the fast path carries no tracer code, so the ratio
must stay ~1 — gated as ``max_dispatch_ratio``), and the cost of a full
Perfetto export of the traced run's ring buffer (informational).
"""
from __future__ import annotations

import time

from .common import FAST, emit

TRIALS = 32 if FAST else 64
REPS = 3 if FAST else 5
GUARD_CALLS = 200_000
MAX_OFF_PCT = 2.0


def _toy_op(db, points: int):
    from repro.core import (
        ATRegion, AutotunedOp, BasicParams, KernelSpec, ParamSpace, PerfParam,
    )

    space = ParamSpace([PerfParam("i", tuple(range(points)))])
    spec = KernelSpec(
        "bench_obs_toy",
        make_region=lambda bp: ATRegion(
            "bench_obs_toy", space, lambda p: (lambda x: x)
        ),
        shape_class=lambda x: BasicParams.make(kernel="bench_obs_toy"),
        cost_factory=lambda r, b, a, k: (lambda p: float(p["i"]) + 1.0),
    )
    return AutotunedOp(spec, db=db, warm=False, monitor=False)


def _tune_once(tracer) -> float:
    """One full tune (TRIALS measured candidates) under ``tracer``."""
    from repro.core import TuningDB
    from repro.obs import use_tracer

    op = _toy_op(TuningDB(), TRIALS)
    with use_tracer(tracer):
        t0 = time.perf_counter()
        op(_PROBE)
        return time.perf_counter() - t0


class _Probe:
    shape = (8, 8)
    dtype = "float32"


_PROBE = _Probe()


def _dispatch_per_call(tracer) -> float:
    """Finalized fast-path dispatch latency under ``tracer`` (the fast
    path has no tracer code, so off and on must cost the same)."""
    from repro.core import TuningDB
    from repro.obs import use_tracer

    op = _toy_op(TuningDB(), 4)
    op(_PROBE)  # tune + finalize: installs the fast route
    calls = 2000
    best = float("inf")
    with use_tracer(tracer):
        for _ in range(REPS):
            t0 = time.perf_counter()
            for _ in range(calls):
                op.dispatch(_PROBE)
            best = min(best, (time.perf_counter() - t0) / calls)
    return best


def run() -> None:
    from repro.obs import Tracer, current_tracer

    # warm once (imports, first-touch caches) before measuring either side
    _tune_once(None)

    off_s = min(_tune_once(None) for _ in range(REPS))

    on_s, events = float("inf"), 0
    last_tracer = None
    for _ in range(REPS):
        tracer = Tracer(capacity=1 << 16)
        on_s = min(on_s, _tune_once(tracer))
        events = max(events, tracer.emitted)  # guard sites per single run
        last_tracer = tracer

    t0 = time.perf_counter()
    export = last_tracer.to_json()
    export_s = time.perf_counter() - t0
    assert export

    dispatch_off_s = _dispatch_per_call(None)
    dispatch_on_s = _dispatch_per_call(Tracer())
    dispatch_ratio = (
        dispatch_on_s / dispatch_off_s if dispatch_off_s else 1.0
    )

    t0 = time.perf_counter()
    for _ in range(GUARD_CALLS):
        current_tracer()
    guard_s = (time.perf_counter() - t0) / GUARD_CALLS

    # events emitted by the enabled run bound the guard sites the disabled
    # run crossed (each emission sits behind exactly one guard)
    off_overhead_pct = 100.0 * (events * guard_s) / off_s if off_s else 0.0
    on_ratio = on_s / off_s if off_s else 1.0

    emit("obs_overhead/off", off_s, f"trials={TRIALS}")
    emit("obs_overhead/on", on_s, f"events={events}")
    emit("obs_overhead/export", export_s, f"events={events}")
    emit("obs_overhead/dispatch_off", dispatch_off_s, "fast-path no tracer")
    emit("obs_overhead/dispatch_on", dispatch_on_s, "fast-path live tracer")
    emit(
        "obs_overhead/summary", off_s,
        f"off_pct={off_overhead_pct:.3f};on_ratio={on_ratio:.2f}"
        f";dispatch_ratio={dispatch_ratio:.2f}"
        f";events={events};guard_ns={guard_s * 1e9:.1f}"
        f";max_off_pct={MAX_OFF_PCT}",
    )
    if off_overhead_pct > MAX_OFF_PCT:
        raise RuntimeError(
            "disabled-tracing overhead missed its gate: "
            f"{off_overhead_pct:.2f}% > {MAX_OFF_PCT}% of the untraced tune "
            f"(guard={guard_s * 1e9:.0f}ns x {events} sites, off={off_s * 1e3:.2f}ms)"
        )
    if events <= 0:
        raise RuntimeError("traced tune emitted no events — seams lost")


if __name__ == "__main__":
    run()
