"""Paper Fig. 12 — overhead of dynamic degree change on Seism3D
``update_stress``.

The paper measures run-time ``omp_set_num_threads`` switching at ≤1.003×
overall cost (i.e. ~free), concluding frequent run-time re-selection is
viable.  Our analogue: every candidate is AOT-precompiled; per-call the
DegreeController enters the region (switch to tuned degree), dispatches the
precompiled executable, and restores max on exit.  We report
switched-every-call time / fixed-degree time — the Fig-12 ratio.
"""
from __future__ import annotations

import time

import jax

from .common import FAST, emit

from repro.apps import seism3d
from repro.core import DegreeController, ExchangeVariant


def run() -> float:
    key = jax.random.PRNGKey(0)
    dims = seism3d.SEISM_DIMS if not FAST else (("k", 16), ("j", 16), ("i", 16))
    inp = seism3d.make_inputs(key, dims)
    region = seism3d.stress_region(dims, degrees=(1, 8, 32))
    variant = (3, 1)  # directive on outermost k
    points = [{"variant": variant, "degree": d} for d in (1, 8, 32)]
    region.precompile([inp], points=points)

    ctl = DegreeController(max_degree=32)
    ctl.set_tuned("update_stress", 8)
    n = 50 if not FAST else 10

    # fixed-degree baseline (conventional method: max threads, no switching)
    fixed = region.candidate({"variant": variant, "degree": 32})
    jax.block_until_ready(fixed(inp))
    t0 = time.perf_counter()
    for _ in range(n):
        out = fixed(inp)
    jax.block_until_ready(out)
    t_fixed = (time.perf_counter() - t0) / n

    # switch-per-call: enter region (set tuned degree), dispatch, restore
    tuned = region.candidate({"variant": variant, "degree": 8})
    jax.block_until_ready(tuned(inp))
    t0 = time.perf_counter()
    for _ in range(n):
        with ctl.region("update_stress") as d:
            out = region.candidate({"variant": variant, "degree": d})(inp)
    jax.block_until_ready(out)
    t_switch = (time.perf_counter() - t0) / n

    ratio = t_switch / t_fixed
    emit("fig12/fixed_degree32", t_fixed, "")
    emit("fig12/switch_per_call", t_switch, f"overhead_ratio={ratio:.4f}")
    emit(
        "fig12/switches", 0.0,
        f"count={ctl.switch_count};paper_ratio=1.003",
    )
    return ratio


if __name__ == "__main__":
    run()
