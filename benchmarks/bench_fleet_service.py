"""Global tuning service benchmark: convergence under faults (docs/fleet.md).

Runs the ISSUE 7 acceptance scenario deterministically in one process:

1. a **single-process reference** exhaustive run over the demo space;
2. a **2-host remote fleet** through one :class:`TuningService`, each host a
   ``backend="remote"`` :class:`FleetCoordinator` on its own half of the
   space, talking through a seeded
   :class:`~repro.fleet.transport.FaultInjectionTransport` injecting
   dropped requests/responses, duplicated and reordered deliveries — plus
   one full partition/heal cycle on host 1.  All client backoff runs on a
   :class:`VirtualClock`, so the bench takes no real wall time waiting;
3. a **fresh host** (BackgroundTuner with a service client) seeing the same
   traffic class: it must adopt the service's final with **zero** cost
   evaluations (the hot-path invariant at fleet scope).

Gates (all deterministic counts/flags, checked by
``scripts/check_bench_regression.py`` against
``benchmarks/baselines/fleet_service.json``):

* ``entries_equal=1`` — the service's final-best entry is byte-identical
  (point, cost, finality, layer) to the single-process run's;
* ``winner_match=1`` — merged fleet winner == single-process winner;
* ``hot_evals=0`` — the fresh host adopted without measuring;
* ``faults >= min_faults`` — the lossy schedule actually exercised the
  retry/join machinery (a quiet injector would gate nothing).

Rows: ``fleet_service/host<i>`` per host (wall seconds, fault/retry
counts) and the gated ``fleet_service/summary``.
"""
from __future__ import annotations

import json
import time

from .common import emit


def run() -> None:
    from repro.core import BasicParams, TuningDB
    from repro.fleet import (
        FaultInjectionTransport,
        FleetCoordinator,
        InProcessTransport,
        ServiceClient,
        TuningService,
        VirtualClock,
    )
    from repro.fleet.workloads import demo_cost, demo_space
    from repro.runtime import BackgroundTuner

    space = demo_space()
    bp = BasicParams.make(kernel="bench_fleet_service")

    # 1. single-process reference
    single = FleetCoordinator(workers=1).search(space, demo_cost, bp=bp)

    # 2. two hosts through one service over a deliberately lossy link
    service = TuningService()
    injectors, clients = [], []
    synced = 0
    for host in range(2):
        clock = VirtualClock()
        ft = FaultInjectionTransport(
            InProcessTransport(service), seed=7 + host,
            drop_request=0.2, drop_response=0.2, duplicate=0.2, reorder=0.1,
        )
        client = ServiceClient(ft, retries=6, jitter_seed=host,
                               sleep=clock.sleep, now=clock.now)
        injectors.append(ft)
        clients.append(client)
        if host == 1:  # one full partition/heal cycle mid-run
            ft.partition()
            client.try_push(TuningDB())  # rejected: the host rides it out
            ft.heal()
        t0 = time.perf_counter()
        fleet = FleetCoordinator(
            workers=2, backend="remote", service=client,
            hosts=2, host_index=host, sync_every=2,
        ).search(space, demo_cost, bp=bp)
        wall = time.perf_counter() - t0
        synced += int(bool(fleet.service_synced))
        s = ft.stats
        emit(
            f"fleet_service/host{host}", wall,
            f"evals={fleet.evaluations};synced={int(bool(fleet.service_synced))};"
            f"faults={s.faults};retries={client.stats.retries};"
            f"backoff_s={sum(clock.sleeps):.3f}",
        )

    # identical final-best entries vs the single-process run
    fp = bp.fingerprint()
    svc_best = service.db._data.get(fp, {}).get("best")
    ref_best = single.merged._data.get(fp, {}).get("best")
    entries_equal = int(
        json.dumps(svc_best, sort_keys=True, default=str)
        == json.dumps(ref_best, sort_keys=True, default=str)
    )
    winner_match = int(
        service.db.tuned_point(bp) == single.best.point
        and service.db.best_cost(bp) == single.best.cost
        and service.db.trials(bp) == single.merged.trials(bp)
    )

    # 3. a fresh host's BackgroundTuner adopts the final with ZERO cost
    # evaluations: the counting cost callable must never fire
    from repro.core import ATRegion, AutotunedOp, KernelSpec

    hot_evals = 0

    def counting_cost_factory(region, _bp, args, kwargs):
        def cost(point):
            nonlocal hot_evals
            hot_evals += 1
            return demo_cost(point)

        return cost

    spec = KernelSpec(
        name="bench_fleet_service",
        make_region=lambda _bp: ATRegion(
            "svc_bench", space, instantiate=lambda pt: (lambda: pt)
        ),
        shape_class=lambda: bp,  # the exact class the fleet just tuned
        cost_factory=counting_cost_factory,
    )
    fresh_db = TuningDB()
    op = AutotunedOp(spec, db=fresh_db, warm=False)
    adopt_client = ServiceClient(InProcessTransport(service))
    with BackgroundTuner(service=adopt_client) as tuner:
        state = tuner.submit(op)
        tuner.drain(timeout=60)
    adopted = int(
        fresh_db.tuned_point(bp) == single.best.point
        and state.region.selected == single.best.point
        and len(tuner.pulled_labels) == 1
    )

    drops = sum(i.stats.dropped_requests + i.stats.dropped_responses
                for i in injectors)
    dups = sum(i.stats.duplicated for i in injectors)
    reorders = sum(i.stats.reordered for i in injectors)
    partitions = sum(i.stats.partitions for i in injectors)
    healed = sum(i.stats.heals for i in injectors)
    retries = sum(c.stats.retries for c in clients)
    faults = sum(i.stats.faults for i in injectors)

    emit(
        "fleet_service/summary", 0.0,
        f"entries_equal={entries_equal};winner_match={winner_match};"
        f"adopted={adopted};hot_evals={hot_evals};hosts_synced={synced};"
        f"faults={faults};drops={drops};dups={dups};reorders={reorders};"
        f"partitions={partitions};healed={healed};retries={retries}",
    )
    if not (entries_equal and winner_match):
        raise AssertionError(
            "fleet service convergence violated: service final-best != "
            f"single-process (entries_equal={entries_equal}, "
            f"winner_match={winner_match})"
        )


if __name__ == "__main__":
    run()
