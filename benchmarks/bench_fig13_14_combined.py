"""Paper Figs. 13–14 — combined loop-transform × degree AT on GKV.

Fig 13: per-variant best-degree time vs the ORIGINAL loop (speedup, with the
optimal degree in parentheses).  Paper headline: 1.801× total.
Fig 14: per-variant best-degree time vs the same variant at max degree (32) —
the "gain from tuning the degree".  Paper headline: the innermost-directive
variant runs 7.727× faster at 1 thread than at 32 (my-loop length 65 splits
into 2-iteration threads); outermost gains only 1.006×.

We run the full joint exhaustive search through the FIBER tuner (this IS the
before-execution AT of §V) and report both tables.
"""
from __future__ import annotations

import jax

from .common import FAST, emit, time_call

from repro.apps import gkv
from repro.core import (
    BasicParams,
    ExchangeVariant,
    GKV_FIGURE_OF_VARIANT,
    Tuner,
    TuningDB,
    WallClockCost,
    enumerate_exchange_variants,
)

DEGREES = (1, 2, 8, 32) if not FAST else (1, 32)


def run(db_path: str = "results/gkv_tuning.json") -> dict:
    key = jax.random.PRNGKey(0)
    dims = gkv.GKV_DIMS if not FAST else (("iv", 8), ("iz", 8), ("mx", 32), ("my", 17))
    inp = gkv.make_inputs(key, dims)
    region = gkv.exb_region(dims, degrees=DEGREES)

    cost = WallClockCost(
        build=lambda p: (lambda f=jax.jit(region.instantiate(p)): f(inp)),
        warmup=1,
        repeats=2,
    )
    db = TuningDB(db_path)
    bp = BasicParams.make(arch="gkv_exb", dims=tuple(dims), degrees=DEGREES)
    tuner = Tuner(db)
    result = tuner.tune(region, bp, cost)

    costs = {(tuple(t.point["variant"]), t.point["degree"]): t.cost for t in result.trials}
    t_original = costs[((4, 2), max(DEGREES))]

    out = {}
    for v in enumerate_exchange_variants(4):
        fig = GKV_FIGURE_OF_VARIANT[(v.m, v.j)]
        per_degree = {d: costs[((v.m, v.j), d)] for d in DEGREES}
        best_d = min(per_degree, key=per_degree.get)
        t_best = per_degree[best_d]
        t_max = per_degree[max(DEGREES)]
        fig13 = t_original / t_best       # speedup vs original loop
        fig14 = t_max / t_best            # gain from tuning the degree
        out[fig] = (best_d, fig13, fig14)
        emit(
            f"fig13/{fig}", t_best,
            f"best_degree={best_d};speedup_vs_original={fig13:.3f}",
        )
        emit(f"fig14/{fig}", t_max, f"degree_tuning_gain={fig14:.3f}")

    total = t_original / result.best.cost
    emit(
        "fig13/combined_best", result.best.cost,
        f"point={result.best.point};total_speedup={total:.3f};paper=1.801",
    )
    inner = out.get("Fig10:omp@innermost")
    if inner:
        emit(
            "fig14/innermost_inversion", 0.0,
            f"best_degree={inner[0]};gain={inner[2]:.3f};paper=7.727",
        )
    return out


if __name__ == "__main__":
    run()
