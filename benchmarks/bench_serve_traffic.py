"""Serve latency under a mixed prefill/decode trace, with and without
background traffic-class tuning (docs/serving.md).

Three servers replay the same deterministic mixed trace:

* ``inline``     — tuning on the hot path: the first batch of every unseen
  traffic class pays the full search cost in its own latency (the old
  behaviour, the paper's before-execution AT run synchronously).
* ``background`` — unseen classes tune on the worker thread while the hot
  path serves the precompiled default; the replay after drain shows the
  steady state with every class hot-swapped to its winner.
* ``untuned``    — no tuning at all (default candidate forever), the floor.

Rows report p50/p99 per-batch latency; ``derived`` carries the hot-path
cost-evaluation count — the acceptance bar is that background serving shows
``hot_evals=0`` in every phase.
"""
from __future__ import annotations

from .common import FAST, emit


def _percentiles(server) -> tuple:
    return (
        server.stats.latency_percentile(50),
        server.stats.latency_percentile(99),
    )


def run() -> None:
    import jax

    from repro.configs import get_config
    from repro.data import mixed_traffic_trace
    from repro.models import init_params, param_specs
    from repro.runtime import BackgroundTuner, Server

    cfg = get_config("tinyllama-1.1b", smoke=True)
    params = init_params(jax.random.PRNGKey(0), param_specs(cfg))
    n = 8 if FAST else 16
    scale = 0.5 if FAST else 1.0
    trace = mixed_traffic_trace(cfg, n, seed=7, scale=scale)

    def report(tag: str, server, extra: str = "") -> None:
        p50, p99 = _percentiles(server)
        derived = f"hot_evals={server.hot_path_cost_evaluations}"
        if extra:
            derived += f";{extra}"
        emit(f"serve_traffic_{tag}_p50", p50, derived)
        emit(f"serve_traffic_{tag}_p99", p99, derived)

    # Floor: no tuning anywhere, default degree forever.
    untuned = Server(cfg, params, batch_size=2)
    untuned.run(trace)
    report("untuned", untuned)

    # Baseline: tuning cost paid inside request latency.
    inline = Server(cfg, params, batch_size=2, inline_tune=True)
    inline.run(trace)
    report("inline_cold", inline)
    inline.stats.batch_latencies.clear()
    inline.run(trace)
    report("inline_warm", inline)

    # Background: hot path never tunes; steady state after drain is all-tuned.
    with BackgroundTuner() as tuner:
        bg = Server(cfg, params, batch_size=2, background_tuner=tuner)
        bg.run(trace)
        report("background_cold", bg, extra=f"pending={tuner.pending}")
        tuner.drain(timeout=600)
        bg.stats.batch_latencies.clear()
        bg.run(trace)
        report(
            "background_warm", bg,
            extra=(
                f"tuned_classes={len(tuner.tuned_labels)}"
                f";bg_evals={tuner.background_evaluations}"
            ),
        )


if __name__ == "__main__":
    run()
