"""Tuning-throughput benchmark: staged pipeline vs exhaustive measured AT.

For each of the five Pallas kernels this times two complete before-execution
tuning runs over the same candidate space:

* ``exhaustive`` — the paper's strategy: every feasible candidate is
  compiled and wall-clock measured (``REPEATS`` timed runs each).
* ``staged``     — the staged pipeline (docs/tuning.md): the roofline /
  analytic prescreen scores the full space (candidates compiled concurrently,
  nothing executed), only the top-k survivors pay measured evaluations, and
  the measured cost uses variance-aware adaptive repeats.

A third row per warm-start kernel tunes a *second* shape class of the same
kernel against the staged run's DB — the cross-shape-class warm start that
turns a full sweep into a short refinement run.

Acceptance gate (raises, failing the bench run, when missed): the staged
pipeline must do **≥5× fewer measured candidate evaluations and ≥5× fewer
wall-clock timed runs** than exhaustive in aggregate, with every kernel's
chosen candidate **within 5%** of the exhaustive winner's measured cost.

This bench deliberately ignores ``BENCH_FAST``: evaluation counts, the
acceptance gate, and the committed baseline
(``benchmarks/baselines/tune_throughput.json``, enforced by
``scripts/check_bench_regression.py``) must mean the same thing in CI smoke
runs and full runs, so spaces and repeats are identical in both modes.
"""
from __future__ import annotations

import math
import time

from .common import emit

REPEATS = 3  # fixed repeats of the exhaustive baseline (mode-independent)

# prescreen-k per kernel (docs/tuning.md: ~space/6 with a couple of ranks of
# slack for prescreen error; the registry default is ceil(sqrt(n)))
PRESCREEN_K = {
    "flash_attention": 3,
    "ssm_scan": 4,
    "rglru_scan": 4,
    "exb": 4,
    "stress": 5,
}


def _example_args(name, small=False):
    import jax
    import jax.numpy as jnp

    key = jax.random.PRNGKey(0)
    if name == "flash_attention":
        seq = 256 if small else 1024
        q = jax.random.normal(key, (2, seq, 4, 64), jnp.float32)
        return (q, q, q)
    if name == "ssm_scan":
        seq, d = (256, 512) if small else (512, 1024)
        ks = jax.random.split(key, 4)
        x = jax.random.normal(ks[0], (2, seq, d), jnp.float32)
        dt = jnp.full((2, seq, d), 0.01, jnp.float32)
        A = jax.random.normal(ks[1], (d, 16)) * 0.1
        Bc = jax.random.normal(ks[2], (2, seq, 16))
        Cc = jax.random.normal(ks[3], (2, seq, 16))
        D = jnp.ones((d,))
        return (x, dt, A, Bc, Cc, D)
    if name == "rglru_scan":
        seq, w = (256, 512) if small else (512, 1024)
        ks = jax.random.split(key, 3)
        x = jax.random.normal(ks[0], (2, seq, w), jnp.float32)
        r = jax.nn.sigmoid(jax.random.normal(ks[1], (2, seq, w)))
        i = jax.nn.sigmoid(jax.random.normal(ks[2], (2, seq, w)))
        lam = jax.nn.sigmoid(jax.random.normal(key, (w,)))
        return (x, r, i, lam)
    if name == "exb":
        from repro.kernels.exb.ref import make_inputs

        dims = (16, 16, 128, 65) if small else (32, 32, 128, 65)
        return (make_inputs(key, dims=dims),)
    if name == "stress":
        from repro.kernels.stress.ref import make_inputs

        dims = (16, 16, 32) if small else (32, 32, 32)
        return (make_inputs(key, dims=dims),)
    raise KeyError(name)


class _Counter:
    """Measured-evaluation bookkeeping shared by both cost variants."""

    def __init__(self):
        self.points = 0
        self.runs = 0


def _fixed_cost_factory(counter):
    """The exhaustive baseline's measured cost: best-of-``REPEATS``."""
    import jax

    def factory(region, bp, args, kwargs):
        def cost(point):
            counter.points += 1
            fn = region.instantiate(point)
            jax.block_until_ready(fn(*args, **kwargs))  # compile, untimed
            best = math.inf
            for _ in range(REPEATS):
                t0 = time.perf_counter()
                jax.block_until_ready(fn(*args, **kwargs))
                best = min(best, time.perf_counter() - t0)
                counter.runs += 1
            return best

        return cost

    return factory


def _adaptive_cost_factory(counter):
    """The staged run's measured cost: variance-aware adaptive repeats."""
    from repro.core import AdaptiveWallClockCost

    def factory(region, bp, args, kwargs):
        def build(point):
            fn = region.instantiate(point)
            return lambda: fn(*args, **kwargs)

        # max_repeats=3 bounds worst-case staged timed runs to 3 per
        # survivor, so run_ratio >= 5 holds even if every candidate needs
        # its full repeat budget (the gate must never flake on noise)
        inner = AdaptiveWallClockCost(build, warmup=1, min_repeats=2, max_repeats=3)

        def cost(point):
            before = inner.timed_runs
            c = inner(point)
            counter.points += 1
            counter.runs += inner.timed_runs - before
            return c

        return cost

    return factory


def _counting_analytic_factory(counter, spec):
    """exb: the analytic model is the measured layer; one 'run' per point."""

    def factory(region, bp, args, kwargs):
        inner = spec.cost_factory(region, bp, args, kwargs)

        def cost(point):
            counter.points += 1
            counter.runs += 1
            return inner(point)

        return cost

    return factory


def _winner_quality(region, args, staged_point, exhaustive_point, analytic=None,
                    reps=5):
    """staged winner's cost / exhaustive winner's cost, measured head-to-head.

    Judging the staged winner against the exhaustive run's cost *table* is
    biased: the table minimum is a min-of-noisy-mins, so even re-measuring
    the very same candidate scores >1.  Interleaving the two winners' timed
    runs (a/b/a/b...) cancels clock drift; identical winners are 1.0 by
    construction.
    """
    import jax

    from repro.core import pp_key

    if pp_key(staged_point) == pp_key(exhaustive_point):
        return 1.0
    if analytic is not None:
        return analytic(staged_point) / analytic(exhaustive_point)
    fa = region.instantiate(staged_point)
    fb = region.instantiate(exhaustive_point)
    jax.block_until_ready(fa(*args))
    jax.block_until_ready(fb(*args))
    best_a = best_b = math.inf
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fa(*args))
        best_a = min(best_a, time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(fb(*args))
        best_b = min(best_b, time.perf_counter() - t0)
    return best_a / best_b


def run() -> None:
    from repro.core import AutotunedOp, ExhaustiveSearch, TuningDB, get_kernel, pp_key

    totals = {"base_evals": 0, "base_runs": 0, "staged_evals": 0, "staged_runs": 0}
    base_wall = staged_wall = 0.0
    qualities = {}

    for name, k in PRESCREEN_K.items():
        spec = get_kernel(name)
        args = _example_args(name)
        analytic = name == "exb"

        # -- exhaustive baseline (also the ground-truth cost table) --------
        base = _Counter()
        factory = (
            _counting_analytic_factory(base, spec) if analytic
            else _fixed_cost_factory(base)
        )
        op_ex = AutotunedOp(
            spec, db=TuningDB(), search=ExhaustiveSearch(), warm=False,
            monitor=False, warm_start=False, cost_factory=factory,
        )
        t0 = time.time()
        st_ex = op_ex.resolve(*args)
        t_ex = time.time() - t0
        table = op_ex.db.trials(st_ex.bp)
        emit(
            f"tune_throughput/{name}/exhaustive", t_ex,
            f"evals={base.points};runs={base.runs};space={len(table)}",
        )

        # -- staged pipeline ----------------------------------------------
        staged = _Counter()
        factory = (
            _counting_analytic_factory(staged, spec) if analytic
            else _adaptive_cost_factory(staged)
        )
        op_st = AutotunedOp(
            spec, db=TuningDB(), warm=False, monitor=False, warm_start=False,
            prescreen_k=k, cost_factory=factory,
        )
        t0 = time.time()
        st_st = op_st.resolve(*args)
        t_st = time.time() - t0
        exhaustive_winner = dict(st_ex.region.selected)
        analytic_fn = (
            spec.cost_factory(st_st.region, st_st.bp, args, {}) if analytic
            else None
        )
        quality = _winner_quality(
            st_st.region, args, dict(st_st.region.selected), exhaustive_winner,
            analytic=analytic_fn,
        )
        # the count gates are deterministic, but this quality term is a
        # wall-clock measurement: on a violation, re-compare with growing
        # repeat counts and keep the minimum, so a transient load spike
        # cannot fail the gate while a genuinely worse winner still does
        for reps in (9, 13):
            if quality <= 1.05:
                break
            quality = min(quality, _winner_quality(
                st_st.region, args, dict(st_st.region.selected),
                exhaustive_winner, analytic=analytic_fn, reps=reps,
            ))
        qualities[name] = quality
        emit(
            f"tune_throughput/{name}/staged", t_st,
            f"evals={staged.points};runs={staged.runs}"
            f";prescreen={st_st.prescreen_evaluations};k={k}"
            f";quality={quality:.3f};speedup={t_ex / max(t_st, 1e-9):.2f}",
        )

        # -- cross-shape-class warm start: a sibling class refines ---------
        warm = _Counter()
        factory = (
            _counting_analytic_factory(warm, spec) if analytic
            else _adaptive_cost_factory(warm)
        )
        op_warm = AutotunedOp(
            spec, db=op_st.db, warm=False, monitor=False,
            prescreen_k=k, cost_factory=factory,
        )
        t0 = time.time()
        st_warm = op_warm.resolve(*_example_args(name, small=True))
        t_warm = time.time() - t0
        n_sibling = sum(1 for _ in st_warm.region.space.points())
        emit(
            f"tune_throughput/{name}/warm_start", t_warm,
            f"evals={warm.points};space={n_sibling}"
            f";seeded={int(st_warm.warm_seed is not None)}",
        )

        totals["base_evals"] += base.points
        totals["base_runs"] += base.runs
        totals["staged_evals"] += staged.points
        totals["staged_runs"] += staged.runs
        base_wall += t_ex
        staged_wall += t_st

    eval_ratio = totals["base_evals"] / max(1, totals["staged_evals"])
    run_ratio = totals["base_runs"] / max(1, totals["staged_runs"])
    emit(
        "tune_throughput/summary", staged_wall,
        f"eval_ratio={eval_ratio:.2f};run_ratio={run_ratio:.2f}"
        f";base_evals={totals['base_evals']};staged_evals={totals['staged_evals']}"
        f";base_runs={totals['base_runs']};staged_runs={totals['staged_runs']}"
        f";wall_ratio={base_wall / max(staged_wall, 1e-9):.2f}",
    )

    bad_quality = {n: q for n, q in qualities.items() if q > 1.05}
    if eval_ratio < 5.0 or run_ratio < 5.0 or bad_quality:
        raise RuntimeError(
            "staged tuning pipeline missed its acceptance gate: "
            f"eval_ratio={eval_ratio:.2f} run_ratio={run_ratio:.2f} "
            f"(need >=5x), quality violations={bad_quality} (need <=1.05)"
        )


if __name__ == "__main__":
    run()
