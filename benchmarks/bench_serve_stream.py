"""Continuous batching vs static batching under a bursty open-loop trace.

Three servers replay the same deterministic ``bursty_open_loop_trace``
(docs/serving.md) on a virtual clock — arrivals advance the clock, measured
step wall times accumulate on it, idle gaps jump — so time-to-first-token
percentiles are shaped by scheduling, not by sleeps:

* ``static``  — the fixed-batch :class:`~repro.runtime.serve.Server`: a
  group admits only when its last member has arrived, pads mixed prompt
  lengths, and decodes every row to the group max.
* ``engine``  — the :class:`~repro.runtime.engine.StreamingEngine` with
  default scheduler knobs (no tuner attached).
* ``tuned``   — the engine with a :class:`BackgroundTuner`: scheduler-knob
  classes tune off the hot path during the cold pass, the measured pass
  replays with every class hot-swapped to its winner.

Every run is warmed first (jit compiles would otherwise dominate the
virtual clock).  Rows report p99 TTFT; the ``summary`` row carries the
acceptance flags the regression gate reads: the engine must beat the static
server on both p99 TTFT and total tok/s, with zero hot-path tuning
evaluations and at least one tuned scheduler class.
"""
from __future__ import annotations

import time

from .common import FAST, emit


def _static_replay(server, reqs, batch_size):
    """Virtual-clock replay of the fixed-batch server over the open-loop
    trace: group g starts at max(previous finish, last member arrival)."""
    now = reqs[0].arrival_s
    t_first = now
    ttft = []
    tok0 = server.stats.tokens_out
    for i in range(0, len(reqs), batch_size):
        group = reqs[i:i + batch_size]
        start = max(now, max(r.arrival_s for r in group))
        p0 = server.stats.prefill_s
        t0 = time.perf_counter()
        server.run(group)
        dt = time.perf_counter() - t0
        prefill_dt = server.stats.prefill_s - p0
        for r in group:
            ttft.append(start + prefill_dt - r.arrival_s)
        now = start + dt
    import numpy as np

    tokens = server.stats.tokens_out - tok0
    makespan = max(now - t_first, 1e-9)
    return (
        float(np.percentile(np.asarray(ttft), 50)),
        float(np.percentile(np.asarray(ttft), 99)),
        tokens / makespan,
    )


def _engine_replay(engine, reqs, warm=3, tuner=None):
    """Measured engine pass after ``warm`` unmeasured ones.

    Warming needs a fixed point, not one pass: a compile mid-pass slows the
    virtual clock, which changes how the scheduler composes groups, which
    can surface a *new* shape (and a new compile) on the next pass.  A few
    passes exhaust the small set of reachable group shapes.  With a tuner
    attached, each warm pass also drains it — a fresh traffic class
    surfaced mid-pass would otherwise leave its background search running
    *during* the measured pass, and the contention lands on the clock.
    """
    from repro.runtime.engine import StreamStats

    for _ in range(warm):
        engine.stats = StreamStats()
        engine.serve(reqs)
        if tuner is not None:
            tuner.drain(timeout=600)
    engine.stats = StreamStats()
    engine.serve(reqs)
    s = engine.stats
    return s.ttft_percentile(50), s.ttft_percentile(99), s.tok_per_s


def run() -> None:
    import jax

    from repro.configs import get_config
    from repro.data import bursty_open_loop_trace
    from repro.models import init_params, param_specs
    from repro.runtime import BackgroundTuner, Server, StreamingEngine

    cfg = get_config("tinyllama-1.1b", smoke=True)
    params = init_params(jax.random.PRNGKey(0), param_specs(cfg))
    n = 8 if FAST else 16
    scale = 0.25 if FAST else 0.5
    trace = bursty_open_loop_trace(cfg, n, seed=7, scale=scale)
    max_len = max(len(r.prompt) + r.max_new_tokens for r in trace)
    batch = 4

    # -- static fixed-batch baseline ----------------------------------------
    static = Server(cfg, params, batch_size=batch, max_len=max_len)
    static.run(trace)  # warm the per-shape jits off the clock
    st_p50, st_p99, st_tok = _static_replay(static, trace, batch)
    emit("serve_stream_static_p99", st_p99,
         f"ttft_p50={st_p50 * 1e6:.0f};tok_s={st_tok:.1f}")

    # -- engine, default knobs ----------------------------------------------
    eng = StreamingEngine(cfg, params, n_blocks=8, max_len=max_len)
    en_p50, en_p99, en_tok = _engine_replay(eng, trace)
    emit("serve_stream_engine_p99", en_p99,
         f"ttft_p50={en_p50 * 1e6:.0f};tok_s={en_tok:.1f}"
         f";hot_evals={eng.hot_path_cost_evaluations}")

    # -- engine, background-tuned scheduler knobs ---------------------------
    with BackgroundTuner() as tuner:
        tuned = StreamingEngine(
            cfg, params, n_blocks=8, max_len=max_len, background_tuner=tuner
        )
        tuned.serve(trace)            # cold pass: submits every class
        tuner.drain(timeout=600)
        tu_p50, tu_p99, tu_tok = _engine_replay(tuned, trace, tuner=tuner)
        n_sched = len(tuned.tuned_scheduler_classes)
        emit("serve_stream_tuned_p99", tu_p99,
             f"ttft_p50={tu_p50 * 1e6:.0f};tok_s={tu_tok:.1f}"
             f";hot_evals={tuned.hot_path_cost_evaluations}"
             f";tuned_sched={n_sched}"
             f";bg_evals={tuner.background_evaluations}")

    best_p99 = min(en_p99, tu_p99)
    best_tok = max(en_tok, tu_tok)
    emit(
        "serve_stream/summary",
        best_p99,
        f"engine_beats_static_p99={int(best_p99 < st_p99)}"
        f";engine_beats_static_tok={int(best_tok > st_tok)}"
        f";p99_ratio={st_p99 / max(best_p99, 1e-9):.2f}"
        f";tok_ratio={best_tok / max(st_tok, 1e-9):.2f}"
        f";hot_evals={eng.hot_path_cost_evaluations + tuned.hot_path_cost_evaluations}"
        f";tuned_sched={n_sched}",
    )


if __name__ == "__main__":
    run()
