"""Dispatch-overhead microbenchmark: slow resolution vs the fast path.

Before this PR every ``AutotunedOp`` call paid full shape-class resolution:
extract the BP from the call arguments, JSON-serialize + SHA-256 it into a
fingerprint, take the state lock, walk to the state, then ``pp_key`` the
selection into the candidate table.  Once a shape class is *final* none of
that can change the answer, so dispatch now collapses to one dict lookup on
a structural key (docs/program.md).

This bench times exactly the dispatch decision (``op.dispatch`` returns the
callable without executing it) for a finalized shape class:

* ``slow`` — an op with ``fast_dispatch=False`` over the same tuned DB: the
  pre-PR per-call path (resolution is a cache hit — no tuning is timed);
* ``fast`` — the fast path: structural key → dict hit → bound callable.

Gate (raise when missed; CI re-checks against
``benchmarks/baselines/dispatch.json``): fast dispatch must be **>= 10x**
cheaper per call.  Both sides are pure Python measured best-of-``REPS`` over
``CALLS`` calls, so the ratio is stable across machines.
"""
from __future__ import annotations

import time

from .common import emit

CALLS = 4000
REPS = 5
MIN_SPEEDUP = 10.0


def _toy_op(db):
    from repro.core import (
        ATRegion, AutotunedOp, BasicParams, KernelSpec, ParamSpace, PerfParam,
    )

    space = ParamSpace([PerfParam("i", (0, 1, 2, 3))])
    spec = KernelSpec(
        "bench_dispatch_toy",
        make_region=lambda bp: ATRegion(
            "bench_dispatch_toy", space, lambda p: (lambda x: x)
        ),
        shape_class=lambda x: BasicParams.make(
            kernel="bench_dispatch_toy", n=int(x.shape[0]), dtype=str(x.dtype)
        ),
        cost_factory=lambda r, b, a, k: (lambda p: float(p["i"]) + 1.0),
    )
    return AutotunedOp(spec, db=db, warm=False, monitor=False)


def _per_call(fn, x) -> float:
    best = float("inf")
    for _ in range(REPS):
        t0 = time.perf_counter()
        for _ in range(CALLS):
            fn(x)
        best = min(best, (time.perf_counter() - t0) / CALLS)
    return best


def run() -> None:
    import jax.numpy as jnp

    from repro.core import TuningDB

    db = TuningDB()
    x = jnp.ones(8)

    fast_op = _toy_op(db)
    fast_op(x)  # tune + finalize: installs the fast route
    assert fast_op._fast, "shape class did not finalize into the fast path"

    slow_op = _toy_op(db)  # same tuned DB: resolution is a pure cache hit
    slow_op.fast_dispatch = False
    slow_op.dispatch(x)  # materialize the state once (not timed)

    fast_s = _per_call(fast_op.dispatch, x)
    slow_s = _per_call(slow_op.dispatch, x)
    speedup = slow_s / fast_s

    emit("dispatch/slow", slow_s, "per-call full shape-class resolution")
    emit("dispatch/fast", fast_s, "per-call finalized dict-lookup dispatch")
    emit(
        "dispatch/summary", fast_s,
        f"speedup={speedup:.1f};min={MIN_SPEEDUP:.0f}"
        f";slow_us={slow_s * 1e6:.2f};fast_us={fast_s * 1e6:.2f}",
    )
    if speedup < MIN_SPEEDUP:
        raise RuntimeError(
            "fast dispatch missed its acceptance gate: "
            f"{speedup:.1f}x < {MIN_SPEEDUP:.0f}x "
            f"(slow={slow_s * 1e6:.2f}us fast={fast_s * 1e6:.2f}us)"
        )


if __name__ == "__main__":
    run()
