"""Emitted-space benchmark: arch-model candidate generation vs hand ladders.

PR 9 replaced every kernel's hand-enumerated block ladder with spaces
*emitted* from the architecture model (core/arch.py + core/emit.py).  This
bench freezes the old hand ladders (copied verbatim from the pre-emit
``ops.py`` files, 16 MiB VMEM budget) and gates the migration per kernel:

* **superset** — every feasible hand point is still in the emitted space
  (the union escape hatch means the model can only *add* candidates here);
* **winner_le** — under the kernel's deterministic model cost (exb: the
  analytic TPU cost; others: the emit-layer roofline hint) the staged
  winner over the emitted space is never worse than the best hand point —
  by construction given superset, asserted end to end anyway;
* **inbudget** — tuning the emitted space pays no more measured candidate
  evaluations than the staged budget (``PRESCREEN_K``, the PR 3 contract):
  a bigger model-generated space must not inflate measured tuning cost;
* **deterministic** — emitting twice yields byte-identical space
  signatures (the content hash that gates TuningDB final recall).

All four gates are deterministic counts/flags — no wall-clock term, so the
bench means the same thing in CI smoke and full runs (``BENCH_FAST`` is
deliberately ignored).  Raises, failing the bench run, on any violation;
``scripts/check_bench_regression.py`` re-checks the emitted record against
``benchmarks/baselines/emit_space.json``.
"""
from __future__ import annotations

import time

from .common import emit
from .bench_tune_throughput import PRESCREEN_K, _example_args

LEGACY_VMEM_BUDGET = 16 * 2**20  # the hand ladders' hard-coded budget


def _hand_space(name, bp):
    """The frozen pre-emit hand ladder for one kernel (feasible points).

    These are deliberately *copies* of the deleted enumerations, not calls
    into current code: the bench compares the emitted space against what
    the hand-tuned ladders actually were.
    """
    from repro.core import ParamSpace, PerfParam

    if name == "flash_attention":
        from repro.kernels.flash_attention.flash_attention import vmem_bytes

        s, hd = bp["seq"], bp["hd"]
        blocks = tuple(
            b for b in (128, 256, 512, 1024, 2048) if b <= s and s % b == 0
        ) or (s,)
        return ParamSpace(
            [PerfParam("block_q", blocks), PerfParam("block_kv", blocks)],
            constraint=lambda p: vmem_bytes(p["block_q"], p["block_kv"], hd)
            <= LEGACY_VMEM_BUDGET,
        )
    if name == "ssm_scan":
        from repro.kernels.ssm_scan.ssm_scan import vmem_bytes

        d, s, n = bp["d_inner"], bp["seq"], bp["n_state"]
        d_blocks = tuple(
            b for b in (128, 256, 512, 1024, 2048) if b <= d and d % b == 0
        ) or (d,)
        chunks = tuple(
            c for c in (32, 64, 128, 256, 512) if c <= s and s % c == 0
        ) or (s,)
        return ParamSpace(
            [PerfParam("block_d", d_blocks), PerfParam("chunk", chunks)],
            constraint=lambda p: vmem_bytes(p["block_d"], p["chunk"], n)
            <= LEGACY_VMEM_BUDGET,
        )
    if name == "rglru_scan":
        from repro.kernels.rglru_scan.rglru_scan import vmem_bytes

        w, s = bp["width"], bp["seq"]
        w_blocks = tuple(
            b for b in (128, 256, 512, 1024, 2560) if b <= w and w % b == 0
        ) or (w,)
        chunks = tuple(
            c for c in (32, 64, 128, 256, 512) if c <= s and s % c == 0
        ) or (s,)
        return ParamSpace(
            [PerfParam("block_w", w_blocks), PerfParam("chunk", chunks)],
            constraint=lambda p: vmem_bytes(p["block_w"], p["chunk"])
            <= LEGACY_VMEM_BUDGET,
        )
    if name == "exb":
        from repro.kernels.exb.exb import vmem_bytes

        iv, iz, mx, my = bp["iv"], bp["iz"], bp["mx"], bp["my"]
        divisors = lambda n: tuple(
            d for d in (1, 2, 4, 8, 16, 32) if n % d == 0 and d <= n
        )
        return ParamSpace(
            [PerfParam("block_iv", divisors(iv)),
             PerfParam("block_iz", divisors(iz))],
            constraint=lambda p: vmem_bytes(p["block_iv"], p["block_iz"], mx, my)
            <= LEGACY_VMEM_BUDGET,
        )
    if name == "stress":
        from repro.kernels.stress.stress import vmem_bytes

        nk, nj, ni = bp["nk"], bp["nj"], bp["ni"]
        divs = lambda n: tuple(
            d for d in (1, 2, 4, 8, 16, 32, 64) if n % d == 0 and d <= n
        )
        return ParamSpace(
            [PerfParam("block_k", divs(nk)), PerfParam("block_j", divs(nj))],
            constraint=lambda p: vmem_bytes(p["block_k"], p["block_j"], ni)
            <= LEGACY_VMEM_BUDGET,
        )
    raise KeyError(name)


def _model_cost(spec, region, bp, args):
    """The kernel's deterministic model cost over its emitted region.

    exb ships an analytic TPU cost (its measured layer); every other
    kernel's model is the emit hint — both are pure functions of the
    point, so winner comparisons and eval counts cannot flake on noise.
    """
    from repro.core import pp_key

    if spec.name == "exb":
        return spec.cost_factory(region, bp, args, {})
    hints = region.hints
    return lambda point: float(hints[pp_key(point)]["est_s"])


def run() -> None:
    from repro.core import AutotunedOp, TuningDB, get_kernel, pp_key

    flags = {"superset": 0, "winner_le": 0, "inbudget": 0, "deterministic": 0}
    total_emitted = total_hand = 0
    violations = []
    t_all = time.time()

    for name, k in PRESCREEN_K.items():
        spec = get_kernel(name)
        args = _example_args(name)
        bp = spec.shape_class(*args)

        t0 = time.time()
        region = spec.make_region(bp)
        t_emit = time.time() - t0

        emitted_keys = {pp_key(p) for p in region.space.points()}
        hand_points = list(_hand_space(name, bp).points())
        hand_keys = {pp_key(p) for p in hand_points}

        superset = hand_keys <= emitted_keys
        deterministic = (
            spec.make_region(bp).space_signature == region.space_signature
        )

        # staged tune over the emitted space, deterministic measured cost
        evals = []
        model = _model_cost(spec, region, bp, args)

        def factory(r, b, a, kw, _model=model):
            def cost(point):
                evals.append(dict(point))
                return _model(point)

            return cost

        op = AutotunedOp(
            spec, db=TuningDB(), warm=False, monitor=False, warm_start=False,
            prescreen_k=k, cost_factory=factory,
        )
        st = op.resolve(*args)
        inbudget = len(evals) <= k

        emitted_winner = model(dict(st.region.selected))
        hand_winner = min(model(p) for p in hand_points)
        winner_le = emitted_winner <= hand_winner

        for flag, ok in (("superset", superset), ("winner_le", winner_le),
                         ("inbudget", inbudget),
                         ("deterministic", deterministic)):
            if ok:
                flags[flag] += 1
            else:
                violations.append(f"{name}:{flag}")
        total_emitted += len(emitted_keys)
        total_hand += len(hand_keys)

        emit(
            f"emit_space/{name}", t_emit,
            f"emitted={len(emitted_keys)};hand={len(hand_keys)}"
            f";superset={int(superset)};winner_le={int(winner_le)}"
            f";evals={len(evals)};k={k};inbudget={int(inbudget)}"
            f";deterministic={int(deterministic)}"
            f";sig={region.space_signature}",
        )

    n = len(PRESCREEN_K)
    emit(
        "emit_space/summary", time.time() - t_all,
        f"kernels={n};superset={flags['superset']}"
        f";winner_le={flags['winner_le']};inbudget={flags['inbudget']}"
        f";deterministic={flags['deterministic']}"
        f";emitted_points={total_emitted};hand_points={total_hand}",
    )

    if violations:
        raise RuntimeError(
            "emitted candidate spaces missed their acceptance gate: "
            + ", ".join(violations)
        )


if __name__ == "__main__":
    run()
