"""Hardened vs un-hardened engine under the adversarial overload trace.

The trace (:func:`~repro.data.adversarial_trace`) is hostile on purpose:
one-burst arrivals overload a 2-block KV pool behind a 3-deep admission
queue, a fraction of requests carry deadlines and priorities, some are
malformed (empty prompt / zero tokens / over-capacity prompt), one is
forced to an unmeetable deadline, and a seeded
:class:`~repro.runtime.chaos.ChaosInjector` adds transient step faults,
KV-pool squeezes, and virtual delays on top.

Three legs on the same trace:

* ``unhardened`` — the pre-hardening contract (``hardened=False``): the
  first malformed request or injected fault raises and the whole trace is
  lost.  The leg *must* crash — that is the baseline the hardening exists
  to beat, and the gate fails if it stops crashing (the trace went soft).
* ``chaos``      — the hardened engine, warmed, measured with chaos
  attached.  The gates are the drain contract: every request retired
  exactly once with a valid status, ``ok`` outputs bit-identical to the
  one-request-at-a-time oracle (forced-replay recompute preserves this
  across preemptions), every KV block back in the pool, zero hot-path
  tuning evaluations, and at least one shed / timeout / error each so the
  hardened paths demonstrably fired.
* ``healthy``    — the same engine, chaos detached, re-served: proves the
  engine is still serviceable after chaos and provides the like-for-like
  p99 TTFT denominator for the (generously bounded) overload ratio.

Every gated quantity is a deterministic flag/count or a back-to-back
ratio of like timings on one virtual clock — nothing gates on machine
noise.
"""
from __future__ import annotations

from .common import FAST, emit

STATUSES = ("ok", "timed_out", "shed", "error")


def _oracle(cfg, params, reqs, max_len):
    """One-request-at-a-time greedy decode over the well-formed subset."""
    from repro.runtime import Server

    srv = Server(cfg, params, batch_size=1, max_len=max_len)
    out = {}
    for r in reqs:
        if len(r.prompt) >= 1 and 1 <= r.max_new_tokens \
                and len(r.prompt) + r.max_new_tokens <= max_len:
            out.update(srv.run([r]))
    return out


def run() -> None:
    import jax

    from repro.configs import get_config
    from repro.data import adversarial_trace
    from repro.models import init_params, param_specs
    from repro.runtime import ChaosInjector, StreamingEngine
    from repro.runtime.engine import StreamStats

    cfg = get_config("tinyllama-1.1b", smoke=True)
    params = init_params(jax.random.PRNGKey(0), param_specs(cfg))
    n = 8 if FAST else 16
    scale = 0.25 if FAST else 0.5
    max_len = 48 if FAST else 96
    trace = adversarial_trace(
        cfg, n, seed=7, scale=scale,
        burst_size=n,                 # one burst: the queue must overflow
        deadline_fraction=0.4, deadline_ttl_s=0.4,
        priority_levels=3, malformed_rate=0.25, max_len_hint=max_len,
    )
    # force one guaranteed timeout: a well-formed request whose deadline is
    # over before its first decode round can possibly complete
    victim = next(
        r for r in trace if len(r.prompt) >= 1 and r.max_new_tokens >= 1
        and len(r.prompt) + 16 <= max_len
    )
    victim.max_new_tokens = 16
    victim.deadline_s = victim.arrival_s + 1e-6
    oracle = _oracle(cfg, params, trace, max_len)

    def chaos(seed=7):
        return ChaosInjector(
            seed=seed, step_fault_rate=0.15,
            squeeze_rate=0.2, squeeze_hold=2,
            delay_rate=0.2, delay_s=0.02,
        )

    # -- leg 1: the un-hardened engine must crash on this trace -------------
    un = StreamingEngine(
        cfg, params, n_blocks=2, max_len=max_len, hardened=False,
        chaos=chaos(),
    )
    crashed, kind = 0, "none"
    try:
        un.serve(trace)
    except Exception as e:
        crashed, kind = 1, type(e).__name__
    emit("serve_overload_unhardened", 0.0, f"crashed={crashed};kind={kind}")

    # -- leg 2: hardened engine, warmed, measured under chaos ---------------
    eng = StreamingEngine(
        cfg, params, n_blocks=2, max_len=max_len,
        queue_limit=3, default_ttl_s=None, max_preemptions=3,
    )
    for _ in range(3):  # compile every reachable shape off the clock
        eng.stats = StreamStats()
        eng.serve(trace)
    eng.chaos = chaos()
    eng.stats = StreamStats()
    out = eng.serve(trace)
    s = eng.stats
    rids = {r.rid for r in trace}
    drained = int(set(eng.results) == rids and len(eng.results) == len(rids))
    statuses_valid = int(
        all(res.status in STATUSES for res in eng.results.values())
    )
    oracle_match = int(all(
        toks == oracle[rid] for rid, toks in out.items()
    ) and all(
        eng.results[rid].status == "ok" for rid in out
    ))
    blocks_free = int(
        eng.cache.free == eng.cache.n_blocks and not eng.cache.block_table
    )
    counts = {st: 0 for st in STATUSES}
    for res in eng.results.values():
        counts[res.status] += 1
    cs = eng.chaos.stats
    chaos_p99 = s.ttft_percentile(99)
    emit(
        "serve_overload_chaos_p99", chaos_p99,
        f"drained={drained};statuses_valid={statuses_valid}"
        f";oracle_match={oracle_match};blocks_free={blocks_free}"
        f";hot_evals={eng.hot_path_cost_evaluations}"
        f";ok={counts['ok']};timed_out={counts['timed_out']}"
        f";shed={counts['shed']};error={counts['error']}"
        f";faults={cs.faults};squeezes={cs.blocks_squeezed}"
        f";delays={cs.delays};step_faults={s.step_faults}"
        f";preempted={s.preempted}",
    )

    # -- leg 3: chaos detached — still serviceable, healthy p99 -------------
    eng.chaos = None
    eng.stats = StreamStats()
    out_healthy = eng.serve(trace)
    healthy_p99 = eng.stats.ttft_percentile(99)
    healthy_ok = int(all(
        toks == oracle[rid] for rid, toks in out_healthy.items()
    ))
    emit(
        "serve_overload_healthy_p99", healthy_p99,
        f"oracle_match={healthy_ok};ok={len(out_healthy)}",
    )

    emit(
        "serve_overload/summary", chaos_p99,
        f"unhardened_crashes={crashed};drained={drained}"
        f";statuses_valid={statuses_valid};oracle_match={oracle_match & healthy_ok}"
        f";blocks_free={blocks_free}"
        f";hot_evals={eng.hot_path_cost_evaluations}"
        f";timed_out={counts['timed_out']};shed={counts['shed']}"
        f";error={counts['error']};faults={cs.faults}"
        f";p99_ratio={chaos_p99 / max(healthy_p99, 1e-9):.2f}",
    )


if __name__ == "__main__":
    run()
