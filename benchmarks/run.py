"""Benchmark entry point — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run            # full
    BENCH_FAST=1 PYTHONPATH=src python -m benchmarks.run   # reduced domains
"""
from __future__ import annotations

import traceback


def main() -> None:
    print("name,us_per_call,derived")
    from . import (
        bench_fig11_loop_exchange,
        bench_fig12_degree_switch,
        bench_fig13_14_combined,
        bench_roofline,
    )

    for mod in (
        bench_fig11_loop_exchange,
        bench_fig12_degree_switch,
        bench_fig13_14_combined,
        bench_roofline,
    ):
        try:
            mod.run()
        except Exception as e:  # a failing table must not hide the others
            print(f"{mod.__name__},0.0,ERROR={type(e).__name__}:{e}")
            traceback.print_exc()


if __name__ == "__main__":
    main()
