"""Benchmark entry point — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows on stdout and writes the same
rows as a machine-readable perf record to ``BENCH_results.json`` (override
the path with ``BENCH_JSON=...``) — the artifact CI uploads so the bench
trajectory is tracked across commits.

    PYTHONPATH=src python -m benchmarks.run            # full
    BENCH_FAST=1 PYTHONPATH=src python -m benchmarks.run   # reduced domains
"""
from __future__ import annotations

import json
import os
import platform
import sys
import traceback


def main() -> None:
    print("name,us_per_call,derived")
    from . import (
        bench_dispatch,
        bench_emit_space,
        bench_fig11_loop_exchange,
        bench_fig12_degree_switch,
        bench_fig13_14_combined,
        bench_fleet_service,
        bench_fleet_tune,
        bench_obs_overhead,
        bench_roofline,
        bench_serve_overload,
        bench_serve_stream,
        bench_serve_traffic,
        bench_train_step,
        bench_tune_throughput,
        common,
    )

    failures = []
    for mod in (
        bench_fig11_loop_exchange,
        bench_fig12_degree_switch,
        bench_fig13_14_combined,
        bench_roofline,
        bench_serve_traffic,
        bench_serve_stream,
        bench_serve_overload,
        bench_tune_throughput,
        bench_emit_space,
        bench_fleet_tune,
        bench_fleet_service,
        bench_train_step,
        bench_dispatch,
        bench_obs_overhead,
    ):
        try:
            mod.run()
        except Exception as e:  # a failing table must not hide the others
            failures.append(f"{mod.__name__}: {type(e).__name__}: {e}")
            print(f"{mod.__name__},0.0,ERROR={type(e).__name__}:{e}")
            traceback.print_exc()

    import jax

    record = {
        "schema_version": 1,
        "fast": common.FAST,
        "backend": jax.default_backend(),
        "python": platform.python_version(),
        "jax": jax.__version__,
        "rows": common.RESULTS,
        "failures": failures,
    }
    path = os.environ.get("BENCH_JSON", "BENCH_results.json")
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    print(f"wrote {len(common.RESULTS)} rows to {path}", file=sys.stderr)
    if failures or not common.RESULTS:
        # the perf record exists but the trajectory is broken — fail CI
        print(f"{len(failures)} benchmark module(s) failed", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
