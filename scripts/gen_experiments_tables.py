"""Render EXPERIMENTS.md tables from results/*.jsonl.

    PYTHONPATH=src python scripts/gen_experiments_tables.py > results/tables.md
"""
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import SHAPES, get_config, skipped_cells
from repro.models import analytic_step_flops

PEAK = 197e12


def load(path):
    if not os.path.exists(path):
        return []
    return [json.loads(l) for l in open(path) if l.strip()]


def enrich(r):
    cell = SHAPES[r["shape"]]
    mf = analytic_step_flops(get_config(r["arch"]), cell.kind, cell.global_batch, cell.seq_len)
    r["model_flops"] = mf
    t = r["roofline"]
    r["useful_flops_ratio"] = mf / t["hlo_flops"] if t["hlo_flops"] else 0.0
    r["roofline_frac"] = (mf / (r["chips"] * PEAK)) / t["total_s"] if t["total_s"] else 0.0
    return r


def fmt_row(r):
    t = r["roofline"]
    mem = r["memory"]["per_device_total"] / 2**30
    return (
        f"| {r['arch']} | {r['shape']} | {r['mesh']} | {mem:.1f} | "
        f"{t['compute_s']:.2e} | {t['memory_s']:.2e} | {t['collective_s']:.2e} | "
        f"{t['bottleneck']} | {r['useful_flops_ratio']:.3f} | {r['roofline_frac']:.4f} |"
    )


def main():
    base = [enrich(r) for r in load("results/dryrun_baseline.jsonl") if r.get("status") == "ok"]
    print("### §Roofline — baseline table (rule=tp, remat=full, n_micro=1)\n")
    print("| arch | shape | mesh | mem/dev GiB | compute s | memory s | collective s | bottleneck | useful ratio | roofline frac |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in base:
        print(fmt_row(r))
    print()
    print("Skipped by assignment rule:")
    for arch, shape, reason in skipped_cells():
        print(f"- {arch} × {shape}: {reason}")
    print()

    hc = [r for r in load("results/hillclimb.jsonl") if r.get("status") == "ok"]
    if hc:
        print("### §Perf — hillclimb iteration log\n")
        print("| cell/step | rule | n_micro | mem/dev GiB | compute s | memory s | collective s | bottleneck | useful | roofline frac |")
        print("|---|---|---|---|---|---|---|---|---|---|")
        for r in hc:
            r = enrich(r)
            t = r["roofline"]
            mem = r["memory"]["per_device_total"] / 2**30
            print(
                f"| {r.get('label','?')} | {r['rule']} | {r.get('n_micro',1)} | {mem:.1f} | "
                f"{t['compute_s']:.2e} | {t['memory_s']:.2e} | {t['collective_s']:.2e} | "
                f"{t['bottleneck']} | {r['useful_flops_ratio']:.3f} | {r['roofline_frac']:.4f} |"
            )


if __name__ == "__main__":
    main()
