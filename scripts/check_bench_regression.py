#!/usr/bin/env python
"""Gate the perf trajectory against its committed baselines.

Reads the machine-readable bench record (``BENCH_results.json``, written by
``python -m benchmarks.run``; override with ``BENCH_JSON`` or argv[1]) and
checks three gates against ``benchmarks/baselines/``:

* **tune_throughput.json** — the staged pipeline's measured-evaluation
  counts (``tune_throughput/<kernel>/staged`` rows) must stay within
  ``max_regression`` (default >20% fails) of the committed counts;
* **train_step.json** — the whole-program joint tuner
  (``train_step/summary``) must report ``joint_le_greedy=1`` and at least
  ``min_strict_configs`` configs where joint beats greedy strictly;
* **dispatch.json** — the finalized-dispatch fast path
  (``dispatch/summary``) must report at least ``min_speedup`` (10x) lower
  per-call overhead than full shape-class resolution;
* **serve_traffic.json** — background traffic-class serving
  (``serve_traffic_background_*`` rows) must report ``hot_evals=0`` in
  every phase and at least ``min_tuned_classes`` classes tuned off the
  hot path;
* **serve_stream.json** — continuous batching (``serve_stream/summary``)
  must beat the static fixed-batch server on both p99 TTFT and total
  tok/s under the bursty open-loop trace (a back-to-back comparison on
  one process and virtual clock), with ``hot_evals=0`` and at least
  ``min_tuned_sched_classes`` scheduler classes tuned off the hot path;
* **serve_overload.json** — the hardened engine's drain contract
  (``serve_overload/summary``): under the adversarial chaos trace the
  un-hardened engine must crash while the hardened one retires every
  request exactly once with valid statuses, bit-matches the sequential
  oracle on ``ok`` requests, frees every KV block, pays zero hot-path
  evaluations, demonstrably fires the shed/timeout/error paths, and keeps
  chaos p99 TTFT within ``max_p99_ratio`` of the healthy pass;
* **fleet_tune.json** — the sharded fleet search (``fleet_tune/summary``)
  must report identical winners to single-process on every kernel, full
  space coverage, and balanced shards; the wall-clock speedup ratio is
  gated (``min_speedup_full``) only on full (non ``BENCH_FAST``) records
  from multi-core hosts, where the timing is meaningful;
* **fleet_service.json** — the global tuning service
  (``fleet_service/summary``): the 2-host remote fleet over a seeded
  lossy transport must converge to final-best entries byte-identical to
  the single-process run, a fresh host must adopt the final with
  ``hot_evals=0``, and the injected-fault schedule must be non-trivial
  (``min_faults``/``min_partitions``/``min_healed``);
* **emit_space.json** — the arch-model-emitted candidate spaces
  (``emit_space/summary``): on every kernel the emitted space must cover
  the frozen hand ladder (superset), pick a winner no worse than the best
  hand point under the kernel's deterministic model cost, tune within the
  staged measured-eval budget, and emit byte-identical space signatures
  across repeats; total emitted points are floored at
  ``min_emitted_points``.

Every gated quantity is either a deterministic count/flag or a
back-to-back ratio of like timings, so none of the gates flake on machine
noise; improvements print a reminder to re-commit the baseline.
Fails (exit 1) listing every violated gate or missing baselined row.
"""
from __future__ import annotations

import json
import os
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
BASELINES = ROOT / "benchmarks" / "baselines"

ROW_RE = re.compile(r"^tune_throughput/(?P<kernel>[\w.\-]+)/staged$")
EVALS_RE = re.compile(r"(?:^|;)evals=(\d+)")


def _derived_fields(record: dict, name: str) -> dict:
    """``key=value`` pairs from the named row's derived column, or None."""
    for row in record.get("rows", []):
        if row.get("name") == name:
            out = {}
            for part in str(row.get("derived", "")).split(";"):
                k, _, v = part.partition("=")
                if _:
                    out[k] = v
            return out
    return None


def staged_evals(record: dict) -> dict:
    out = {}
    for row in record.get("rows", []):
        m = ROW_RE.match(row.get("name", ""))
        if not m:
            continue
        ev = EVALS_RE.search(row.get("derived", ""))
        if ev:
            out[m.group("kernel")] = int(ev.group(1))
    return out


def check_tune_throughput(record: dict, problems: list, improved: list) -> str:
    with open(BASELINES / "tune_throughput.json") as f:
        baseline = json.load(f)
    limit = float(baseline.get("max_regression", 1.2))
    expected = baseline["staged_evals"]
    actual = staged_evals(record)

    for kernel, base in expected.items():
        got = actual.get(kernel)
        if got is None:
            problems.append(f"{kernel}: no tune_throughput staged row in record")
        elif got > base * limit:
            problems.append(
                f"{kernel}: measured evaluations regressed {base} -> {got} "
                f"(>{limit:.0%} of baseline)"
            )
        elif got < base:
            improved.append(f"{kernel}: {base} -> {got}")

    total = sum(actual.get(k, 0) for k in expected)
    base_total = int(baseline["total_staged_evals"])
    if total > base_total * limit:
        problems.append(
            f"total measured evaluations regressed {base_total} -> {total}"
        )
    return f"tune_throughput: {total} measured evals (baseline {base_total})"


def check_train_step(record: dict, problems: list) -> str:
    with open(BASELINES / "train_step.json") as f:
        baseline = json.load(f)
    fields = _derived_fields(record, "train_step/summary")
    if fields is None:
        problems.append("train_step: no train_step/summary row in record")
        return "train_step: missing"
    if baseline.get("require_joint_le_greedy", True) and fields.get(
        "joint_le_greedy"
    ) != "1":
        problems.append(
            "train_step: joint-tuned step cost exceeded the per-kernel-greedy "
            f"composition (joint_le_greedy={fields.get('joint_le_greedy')})"
        )
    strict = int(fields.get("strict", 0))
    if strict < int(baseline.get("min_strict_configs", 1)):
        problems.append(
            f"train_step: joint strictly better on only {strict} config(s) "
            f"(need >= {baseline.get('min_strict_configs', 1)})"
        )
    configs = int(fields.get("configs", 0))
    if configs < int(baseline.get("min_configs", 1)):
        problems.append(
            f"train_step: only {configs} config(s) benchmarked "
            f"(need >= {baseline.get('min_configs', 1)})"
        )
    return f"train_step: strict joint wins on {strict}/{configs} configs"


def check_dispatch(record: dict, problems: list) -> str:
    with open(BASELINES / "dispatch.json") as f:
        baseline = json.load(f)
    fields = _derived_fields(record, "dispatch/summary")
    if fields is None:
        problems.append("dispatch: no dispatch/summary row in record")
        return "dispatch: missing"
    speedup = float(fields.get("speedup", 0.0))
    floor = float(baseline.get("min_speedup", 10.0))
    if speedup < floor:
        problems.append(
            f"dispatch: fast-path speedup {speedup:.1f}x below the "
            f"{floor:.0f}x gate"
        )
    return f"dispatch: {speedup:.1f}x over slow resolution"


def check_serve_traffic(record: dict, problems: list) -> str:
    with open(BASELINES / "serve_traffic.json") as f:
        baseline = json.load(f)
    tuned = 0
    for phase in ("background_cold", "background_warm"):
        fields = _derived_fields(record, f"serve_traffic_{phase}_p50")
        if fields is None:
            problems.append(f"serve_traffic: no {phase} row in record")
            continue
        if baseline.get("require_hot_evals_zero", True) and fields.get(
            "hot_evals"
        ) != "0":
            problems.append(
                f"serve_traffic: {phase} paid hot-path cost evaluations "
                f"(hot_evals={fields.get('hot_evals')})"
            )
        if phase == "background_warm":
            tuned = int(fields.get("tuned_classes", 0))
            floor = int(baseline.get("min_tuned_classes", 1))
            if tuned < floor:
                problems.append(
                    f"serve_traffic: only {tuned} traffic class(es) tuned "
                    f"off the hot path (need >= {floor})"
                )
            if int(fields.get("bg_evals", 0)) < int(
                baseline.get("min_bg_evals", 1)
            ):
                problems.append(
                    "serve_traffic: background tuner reported "
                    f"{fields.get('bg_evals')} evaluations"
                )
    return f"serve_traffic: {tuned} classes tuned, hot path clean"


def check_serve_stream(record: dict, problems: list) -> str:
    with open(BASELINES / "serve_stream.json") as f:
        baseline = json.load(f)
    fields = _derived_fields(record, "serve_stream/summary")
    if fields is None:
        problems.append("serve_stream: no serve_stream/summary row in record")
        return "serve_stream: missing"
    if baseline.get("require_hot_evals_zero", True) and fields.get(
        "hot_evals"
    ) != "0":
        problems.append(
            "serve_stream: engine paid hot-path cost evaluations "
            f"(hot_evals={fields.get('hot_evals')})"
        )
    if baseline.get("require_engine_beats_static_p99", True) and fields.get(
        "engine_beats_static_p99"
    ) != "1":
        problems.append(
            "serve_stream: engine p99 TTFT did not beat the static server "
            f"(ratio {fields.get('p99_ratio')})"
        )
    if baseline.get("require_engine_beats_static_tok", True) and fields.get(
        "engine_beats_static_tok"
    ) != "1":
        problems.append(
            "serve_stream: engine tok/s did not beat the static server "
            f"(ratio {fields.get('tok_ratio')})"
        )
    sched = int(fields.get("tuned_sched", 0))
    floor = int(baseline.get("min_tuned_sched_classes", 1))
    if sched < floor:
        problems.append(
            f"serve_stream: only {sched} scheduler class(es) tuned off the "
            f"hot path (need >= {floor})"
        )
    return (
        f"serve_stream: {fields.get('p99_ratio')}x p99 TTFT / "
        f"{fields.get('tok_ratio')}x tok/s over static, "
        f"{sched} scheduler classes tuned"
    )


def check_serve_overload(record: dict, problems: list) -> str:
    with open(BASELINES / "serve_overload.json") as f:
        baseline = json.load(f)
    fields = _derived_fields(record, "serve_overload/summary")
    if fields is None:
        problems.append(
            "serve_overload: no serve_overload/summary row in record"
        )
        return "serve_overload: missing"
    if baseline.get("require_unhardened_crash", True) and fields.get(
        "unhardened_crashes"
    ) != "1":
        problems.append(
            "serve_overload: the un-hardened engine survived the adversarial "
            "trace — the crash baseline went soft, the hardening gate proves "
            "nothing"
        )
    for key, what in (
        ("drained", "some request was never retired"),
        ("statuses_valid", "a request retired with an unknown status"),
        ("oracle_match", "an ok request's tokens diverged from the "
                         "sequential oracle"),
        ("blocks_free", "KV blocks leaked after the drain"),
    ):
        if baseline.get(f"require_{key}", True) and fields.get(key) != "1":
            problems.append(f"serve_overload: {what} ({key}={fields.get(key)})")
    if baseline.get("require_hot_evals_zero", True) and fields.get(
        "hot_evals"
    ) != "0":
        problems.append(
            "serve_overload: hardened serve paid hot-path cost evaluations "
            f"(hot_evals={fields.get('hot_evals')})"
        )
    for key, floor_key in (("timed_out", "min_timed_out"),
                           ("shed", "min_shed"),
                           ("error", "min_error"),
                           ("faults", "min_faults")):
        got = int(fields.get(key, 0))
        floor = int(baseline.get(floor_key, 1))
        if got < floor:
            problems.append(
                f"serve_overload: {key}={got} — that hardened path never "
                f"fired (need >= {floor}); the drain gate proved nothing"
            )
    ratio = float(fields.get("p99_ratio", 0.0))
    cap = float(baseline.get("max_p99_ratio", 100.0))
    if ratio > cap:
        problems.append(
            f"serve_overload: chaos p99 TTFT blew up to {ratio:.1f}x the "
            f"healthy pass (cap {cap:.0f}x)"
        )
    return (
        f"serve_overload: unhardened crashes, hardened drains "
        f"({fields.get('timed_out')} timed out/{fields.get('shed')} shed/"
        f"{fields.get('error')} error) under {fields.get('faults')} faults, "
        f"p99 {ratio:.1f}x healthy"
    )


def check_fleet_tune(record: dict, problems: list) -> str:
    with open(BASELINES / "fleet_tune.json") as f:
        baseline = json.load(f)
    fields = _derived_fields(record, "fleet_tune/summary")
    if fields is None:
        problems.append("fleet_tune: no fleet_tune/summary row in record")
        return "fleet_tune: missing"
    kernels = int(fields.get("kernels", 0))
    match = int(fields.get("winners_match", 0))
    if baseline.get("require_winners_match", True) and match != kernels:
        problems.append(
            f"fleet_tune: sharded winner != single-process winner on "
            f"{kernels - match}/{kernels} kernel(s)"
        )
    if baseline.get("require_covered", True) and fields.get("covered") != "1":
        problems.append("fleet_tune: fleet evaluations != |space| "
                        "(shards lost or duplicated candidates)")
    if baseline.get("require_balanced", True) and fields.get("balanced") != "1":
        problems.append("fleet_tune: shard sizes differ by more than one")
    speedup = float(fields.get("speedup", 0.0))
    # the thread fleet overlaps XLA compilation across cores, so the
    # wall-clock gate only means something with real parallel headroom:
    # skip it on single-core runners (cores recorded by the bench; absent
    # in pre-PR-9 records, where multi-core is assumed as before)
    cores = int(fields.get("cores", 2))
    if not record.get("fast") and cores > 1:
        floor = float(baseline.get("min_speedup_full", 1.0))
        if speedup < floor:
            problems.append(
                f"fleet_tune: {int(fields.get('workers', 0))}-worker search "
                f"throughput scaled only {speedup:.2f}x "
                f"(full-mode gate >= {floor:.2f}x)"
            )
    return (f"fleet_tune: winners {match}/{kernels}, "
            f"{speedup:.2f}x with {fields.get('workers')} workers")


def check_fleet_service(record: dict, problems: list) -> str:
    with open(BASELINES / "fleet_service.json") as f:
        baseline = json.load(f)
    fields = _derived_fields(record, "fleet_service/summary")
    if fields is None:
        problems.append("fleet_service: no fleet_service/summary row in record")
        return "fleet_service: missing"
    if baseline.get("require_entries_equal", True) and fields.get(
        "entries_equal"
    ) != "1":
        problems.append(
            "fleet_service: service final-best entry != single-process entry "
            "(the faulty-schedule convergence gate)"
        )
    if baseline.get("require_winner_match", True) and fields.get(
        "winner_match"
    ) != "1":
        problems.append(
            "fleet_service: fleet winner through the service != "
            "single-process winner"
        )
    if baseline.get("require_adopted", True) and fields.get("adopted") != "1":
        problems.append(
            "fleet_service: fresh host failed to adopt the service final"
        )
    if baseline.get("require_hot_evals_zero", True) and fields.get(
        "hot_evals"
    ) != "0":
        problems.append(
            "fleet_service: pull adoption paid cost evaluations "
            f"(hot_evals={fields.get('hot_evals')})"
        )
    synced = int(fields.get("hosts_synced", 0))
    if synced < int(baseline.get("min_hosts_synced", 2)):
        problems.append(
            f"fleet_service: only {synced} host(s) reconciled with the "
            f"service (need >= {baseline.get('min_hosts_synced', 2)})"
        )
    for key, floor_key in (("faults", "min_faults"),
                           ("partitions", "min_partitions"),
                           ("healed", "min_healed")):
        got = int(fields.get(key, 0))
        floor = int(baseline.get(floor_key, 1))
        if got < floor:
            problems.append(
                f"fleet_service: {key}={got} — the fault schedule went "
                f"quiet (need >= {floor}); the convergence gate proved "
                "nothing"
            )
    return (f"fleet_service: converged under {fields.get('faults')} faults "
            f"({fields.get('drops')} drops/{fields.get('dups')} dups/"
            f"{fields.get('reorders')} reorders), "
            f"{fields.get('retries')} retries, hot path clean")


def check_emit_space(record: dict, problems: list) -> str:
    with open(BASELINES / "emit_space.json") as f:
        baseline = json.load(f)
    fields = _derived_fields(record, "emit_space/summary")
    if fields is None:
        problems.append("emit_space: no emit_space/summary row in record")
        return "emit_space: missing"
    kernels = int(fields.get("kernels", 0))
    want = int(baseline.get("kernels", 5))
    if kernels < want:
        problems.append(
            f"emit_space: only {kernels} kernel(s) emitted (need >= {want})"
        )
    for flag, req_key in (("superset", "require_superset_all"),
                          ("winner_le", "require_winner_le_all"),
                          ("inbudget", "require_inbudget_all"),
                          ("deterministic", "require_deterministic_all")):
        if baseline.get(req_key, True) and int(fields.get(flag, 0)) < kernels:
            problems.append(
                f"emit_space: {flag} held on only {fields.get(flag)}/{kernels} "
                "kernels (the arch-model spaces must cover the hand ladders, "
                "never pick a worse winner, stay in the staged eval budget, "
                "and emit reproducibly)"
            )
    emitted = int(fields.get("emitted_points", 0))
    floor = int(baseline.get("min_emitted_points", 1))
    if emitted < floor:
        problems.append(
            f"emit_space: emitted spaces shrank to {emitted} total points "
            f"(baseline floor {floor}) — the arch model lost coverage"
        )
    return (f"emit_space: {emitted} emitted vs {fields.get('hand_points')} "
            f"hand points across {kernels} kernels, all gates held")


def check_obs_overhead(record: dict, problems: list) -> str:
    with open(BASELINES / "obs_overhead.json") as f:
        baseline = json.load(f)
    fields = _derived_fields(record, "obs_overhead/summary")
    if fields is None:
        problems.append("obs_overhead: no obs_overhead/summary row in record")
        return "obs_overhead: missing"
    off_pct = float(fields.get("off_pct", 100.0))
    ceiling = float(baseline.get("max_off_pct", 2.0))
    if off_pct > ceiling:
        problems.append(
            f"obs_overhead: disabled-tracing overhead {off_pct:.2f}% above "
            f"the {ceiling}% gate — the guards leaked onto a hot loop"
        )
    on_ratio = float(fields.get("on_ratio", 0.0))
    max_on = float(baseline.get("max_on_ratio", 5.0))
    if on_ratio > max_on:
        problems.append(
            f"obs_overhead: enabled-tracing ratio {on_ratio:.2f}x above the "
            f"{max_on}x canary — the tracer itself got expensive"
        )
    dispatch_ratio = float(fields.get("dispatch_ratio", 0.0))
    max_dispatch = float(baseline.get("max_dispatch_ratio", 1.5))
    if dispatch_ratio > max_dispatch:
        problems.append(
            f"obs_overhead: fast-path dispatch slowed {dispatch_ratio:.2f}x "
            f"under a live tracer (gate {max_dispatch}x) — tracer code "
            "leaked onto the dispatch fast path"
        )
    events = int(fields.get("events", 0))
    floor = int(baseline.get("min_events", 1))
    if events < floor:
        problems.append(
            f"obs_overhead: traced tune emitted only {events} events "
            f"(need >= {floor}) — the tuner seams went quiet"
        )
    return (f"obs_overhead: off {off_pct:.2f}% / on {on_ratio:.2f}x / "
            f"dispatch {dispatch_ratio:.2f}x over {events} events")


def main() -> int:
    bench_path = Path(
        sys.argv[1] if len(sys.argv) > 1
        else os.environ.get("BENCH_JSON", "BENCH_results.json")
    )
    if not bench_path.exists():
        print(f"check_bench_regression: {bench_path} not found "
              "(run `python -m benchmarks.run` first)", file=sys.stderr)
        return 1
    with open(bench_path) as f:
        record = json.load(f)

    problems: list = []
    improved: list = []
    summaries = [
        check_tune_throughput(record, problems, improved),
        check_train_step(record, problems),
        check_dispatch(record, problems),
        check_serve_traffic(record, problems),
        check_serve_stream(record, problems),
        check_serve_overload(record, problems),
        check_fleet_tune(record, problems),
        check_fleet_service(record, problems),
        check_emit_space(record, problems),
        check_obs_overhead(record, problems),
    ]

    for p in problems:
        print(f"REGRESSION: {p}", file=sys.stderr)
    if improved and not problems:
        print("improvement — consider re-committing the baseline: "
              + ", ".join(improved))
    if not problems:
        print("bench regression check OK: " + "; ".join(summaries))
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
