#!/usr/bin/env python
"""Gate the tuning-throughput perf trajectory against its committed baseline.

Reads the machine-readable bench record (``BENCH_results.json``, written by
``python -m benchmarks.run``; override with ``BENCH_JSON`` or argv[1]) and
compares the staged pipeline's measured-evaluation counts from the
``tune_throughput/<kernel>/staged`` rows against
``benchmarks/baselines/tune_throughput.json``.

Fails (exit 1) when any kernel's measured-evaluation count — or the total —
regresses more than ``max_regression`` (default 1.2, i.e. >20%) over the
committed baseline, or when a baselined kernel is missing from the record.
Counts are deterministic (prescreen-k per kernel), so this never flakes on
machine noise; improvements print a reminder to re-commit the baseline.
"""
from __future__ import annotations

import json
import os
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
BASELINE = ROOT / "benchmarks" / "baselines" / "tune_throughput.json"

ROW_RE = re.compile(r"^tune_throughput/(?P<kernel>[\w.\-]+)/staged$")
EVALS_RE = re.compile(r"(?:^|;)evals=(\d+)")


def staged_evals(record: dict) -> dict:
    out = {}
    for row in record.get("rows", []):
        m = ROW_RE.match(row.get("name", ""))
        if not m:
            continue
        ev = EVALS_RE.search(row.get("derived", ""))
        if ev:
            out[m.group("kernel")] = int(ev.group(1))
    return out


def main() -> int:
    bench_path = Path(
        sys.argv[1] if len(sys.argv) > 1
        else os.environ.get("BENCH_JSON", "BENCH_results.json")
    )
    if not bench_path.exists():
        print(f"check_bench_regression: {bench_path} not found "
              "(run `python -m benchmarks.run` first)", file=sys.stderr)
        return 1
    with open(bench_path) as f:
        record = json.load(f)
    with open(BASELINE) as f:
        baseline = json.load(f)

    limit = float(baseline.get("max_regression", 1.2))
    expected = baseline["staged_evals"]
    actual = staged_evals(record)

    problems = []
    improved = []
    for kernel, base in expected.items():
        got = actual.get(kernel)
        if got is None:
            problems.append(f"{kernel}: no tune_throughput staged row in record")
        elif got > base * limit:
            problems.append(
                f"{kernel}: measured evaluations regressed {base} -> {got} "
                f"(>{limit:.0%} of baseline)"
            )
        elif got < base:
            improved.append(f"{kernel}: {base} -> {got}")

    total = sum(actual.get(k, 0) for k in expected)
    base_total = int(baseline["total_staged_evals"])
    if total > base_total * limit:
        problems.append(
            f"total measured evaluations regressed {base_total} -> {total}"
        )

    for p in problems:
        print(f"REGRESSION: {p}", file=sys.stderr)
    if improved and not problems:
        print("improvement — consider re-committing the baseline: "
              + ", ".join(improved))
    if not problems:
        print(f"bench regression check OK: {total} measured evaluations "
              f"(baseline {base_total}, limit {limit:.0%})")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
