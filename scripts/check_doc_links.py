#!/usr/bin/env python
"""Verify that documentation cross-links resolve.

Checks, over README.md and docs/*.md:

* every relative markdown link ``[text](target)`` points at a file that
  exists (anchors after ``#`` are stripped; absolute URLs are skipped);
* every ``docs/design.md §N`` reference in docs/ and src/ names a section
  heading that actually exists in docs/design.md (the class of dangling
  reference this script was added to prevent).

Exits non-zero listing every broken link.  CI runs this; so does
tests/test_docs.py.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SECTION_REF_RE = re.compile(r"design\.md\s+§(\d+)")


def doc_files() -> list:
    return [ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))]


def check_markdown_links() -> list:
    broken = []
    for md in doc_files():
        if not md.exists():
            continue
        for m in LINK_RE.finditer(md.read_text()):
            target = m.group(1).split("#")[0]
            if not target or "://" in target or target.startswith("mailto:"):
                continue
            if not (md.parent / target).exists():
                broken.append(f"{md.relative_to(ROOT)}: broken link -> {target}")
    return broken


def check_design_section_refs() -> list:
    design = ROOT / "docs" / "design.md"
    if not design.exists():
        return ["docs/design.md does not exist"]
    sections = set(re.findall(r"^##\s+§(\d+)", design.read_text(), re.MULTILINE))
    broken = []
    sources = [
        *doc_files(),
        *sorted((ROOT / "src").rglob("*.py")),
        *sorted((ROOT / "tests").glob("*.py")),
    ]
    for path in sources:
        for m in SECTION_REF_RE.finditer(path.read_text()):
            if m.group(1) not in sections:
                broken.append(
                    f"{path.relative_to(ROOT)}: dangling reference to "
                    f"design.md §{m.group(1)} (have §{sorted(sections)})"
                )
    return broken


def main() -> int:
    broken = check_markdown_links() + check_design_section_refs()
    for b in broken:
        print(b, file=sys.stderr)
    if broken:
        print(f"{len(broken)} broken doc link(s)", file=sys.stderr)
        return 1
    print(f"doc links OK across {len(doc_files())} files")
    return 0


if __name__ == "__main__":
    sys.exit(main())
